"""Benchmark harness — one function per paper table/claim plus the
roofline-table generator. Prints ``name,us_per_call,derived`` CSV rows and
writes each suite's rows to ``BENCH_<suite>.json`` (the CI bench-smoke
artifact, so the perf trajectory is captured per-PR).

Paper analogues:
  fps_host_loop     — PolyBeast throughput (frames/s): DynamicBatcher +
                      actor threads + learner queue (the §4 FPS claim).
  fps_on_device     — the TPU-native (Anakin) rollout+learn step FPS.
  learner_step      — batched IMPALA learner step latency.
  vtrace            — V-trace computation (scan and Pallas-interpret paths).
  pipeline          — sync vs double-buffered rollout-learn overlap FPS.
  scaling           — data-parallel sharded learner FPS vs mesh size
                      (1/2/4/8 devices; forces 8 host CPU devices via
                      XLA_FLAGS when requested).
  replay            — off-policy replay (core/replay.py): FPS + frames to
                      the catch solve threshold for replay off/uniform/
                      elite at a 1:1 replay ratio, and gridworld return at
                      a fixed frame budget.
  attention         — chunked-vs-dense attention latency (model path).
  kernels           — xla vs Pallas kernel per hot-path op (flash/decode/
                      ssd/vtrace) with achieved-vs-roofline accounting.
  dynamic_batcher   — batching overhead per request.
  generate          — serving decode throughput (tokens/s).
  roofline_table    — re-prints the dry-run roofline terms per (arch, shape)
                      from experiments/dryrun (run launch.dryrun first).

``--suite`` may be given multiple times (``--suite pipeline --suite
replay``); ``--small`` shrinks every suite to CI-smoke scale.
"""

from __future__ import annotations

import glob
import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

SMALL = False        # set by --small: CI-smoke scale
_RESULTS = []        # rows of the suite currently running (JSON artifact)


def row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)
    _RESULTS.append({"name": name, "us_per_call": round(us, 1),
                     "derived": derived})


def timeit(fn, n=20, warmup=3):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


# ---------------------------------------------------------------------------

def bench_vtrace():
    from repro.core.vtrace import vtrace_from_importance_weights
    from repro.kernels import ops
    t, b = 80, 256
    rng = np.random.default_rng(0)
    args = [jnp.asarray(rng.normal(0, 1, (t, b)), jnp.float32)
            for _ in range(4)] + [jnp.asarray(rng.normal(0, 1, (b,)),
                                              jnp.float32)]
    f = jax.jit(vtrace_from_importance_weights)
    us = timeit(lambda: jax.block_until_ready(f(*args)))
    row("vtrace_scan_T80_B256", us, f"{t*b/us:.1f}steps/us")

    g = jax.jit(ops.vtrace_from_importance_weights_kernel)
    us = timeit(lambda: jax.block_until_ready(g(*args)), n=3)
    row("vtrace_pallas_interp_T80_B256", us, "interpret-mode")


def bench_learner_step():
    from repro.configs.atari_impala import small_train
    from repro.core import learner as L
    from repro.envs import catch
    from repro.models.convnet import init_agent, minatar_net
    from repro.optim import make_optimizer
    env = catch.make()
    tc = small_train(unroll_length=20, batch_size=32)
    init_fn, apply_fn = minatar_net(env.obs_shape, env.num_actions)
    params, _ = init_agent(init_fn, jax.random.PRNGKey(0))
    opt = make_optimizer(tc)
    opt_state = opt.init(params)
    step = jax.jit(L.make_train_step(apply_fn, opt, tc))
    rng = np.random.default_rng(0)
    t, b = tc.unroll_length, tc.batch_size
    batch = {
        "obs": jnp.asarray(rng.random((t + 1, b) + env.obs_shape),
                           jnp.float32),
        "action": jnp.asarray(rng.integers(0, 3, (t, b)), jnp.int32),
        "behavior_logits": jnp.asarray(rng.normal(0, 1, (t, b, 3)),
                                       jnp.float32),
        "reward": jnp.asarray(rng.normal(0, 1, (t, b)), jnp.float32),
        "done": jnp.asarray(rng.random((t, b)) > 0.9),
    }
    us = timeit(lambda: jax.block_until_ready(
        step(params, opt_state, jnp.int32(0), batch)[2]["loss"]))
    row("learner_step_T20_B32", us, f"{t*b/(us/1e6):.0f}fps")


def bench_fps_on_device(steps=30):
    """Compiled rollout+learn (the PolyBeast->TPU adaptation)."""
    from repro.configs.atari_impala import small_train
    from repro.core import learner as L, rollout as R
    from repro.envs import catch
    from repro.models.convnet import init_agent, minatar_net
    from repro.optim import make_optimizer
    env = catch.make()
    tc = small_train(unroll_length=20, batch_size=32)
    init_fn, apply_fn = minatar_net(env.obs_shape, env.num_actions)
    params, _ = init_agent(init_fn, jax.random.PRNGKey(0))
    opt = make_optimizer(tc)
    opt_state = opt.init(params)
    key = jax.random.PRNGKey(1)
    carry = R.env_reset_batch(env, key, tc.batch_size)
    unroll = R.make_unroll(env, apply_fn, tc.unroll_length)
    train_step = L.make_train_step(apply_fn, opt, tc)

    @jax.jit
    def combined(params, opt_state, step, carry, key):
        carry, ro = unroll(params, carry, key)
        params, opt_state, m = train_step(params, opt_state, step, ro)
        return params, opt_state, carry, m

    params, opt_state, carry, _ = combined(params, opt_state, jnp.int32(0),
                                           carry, key)
    t0 = time.perf_counter()
    m = None
    for s in range(steps):
        key, k = jax.random.split(key)
        params, opt_state, carry, m = combined(
            params, opt_state, jnp.int32(s), carry, k)
    jax.block_until_ready(m["loss"])
    dt = time.perf_counter() - t0
    frames = steps * tc.batch_size * tc.unroll_length
    row("fps_on_device_catch", dt / steps * 1e6, f"{frames/dt:.0f}fps")


def bench_pipeline(steps=60, repeats=3):
    """Synchronous vs double-buffered rollout-learn overlap (the Runtime's
    pipelined DeviceSource): same unroll + learner step, with and without
    one-step-lag double buffering."""
    if SMALL:
        steps, repeats = 20, 1
    from repro.configs.atari_impala import small_train
    from repro.core import learner as L
    from repro.core.sources import DeviceSource
    from repro.envs import catch, gridworld
    from repro.models.convnet import init_agent, minatar_net
    from repro.optim import make_optimizer

    for env_name, env_mod in (("catch", catch), ("gridworld", gridworld)):
        env = env_mod.make()
        tc = small_train(unroll_length=20, batch_size=32)
        init_fn, apply_fn = minatar_net(env.obs_shape, env.num_actions)
        params0, _ = init_agent(init_fn, jax.random.PRNGKey(0))
        opt = make_optimizer(tc)
        step_fn = jax.jit(L.make_train_step(apply_fn, opt, tc))
        fps = {}
        for pipelined in (False, True):
            best = 0.0
            for rep in range(repeats):
                source = DeviceSource.for_env(
                    env, apply_fn, unroll_length=tc.unroll_length,
                    batch_size=tc.batch_size, key=jax.random.PRNGKey(1),
                    pipelined=pipelined)
                params, opt_state = params0, opt.init(params0)
                m = None
                for s in range(5):  # warmup: compile unroll + learner step
                    batch = source.next_batch(params)
                    params, opt_state, m = step_fn(params, opt_state,
                                                   jnp.int32(s), batch)
                jax.block_until_ready(m["loss"])
                t0 = time.perf_counter()
                for s in range(steps):
                    batch = source.next_batch(params)
                    params, opt_state, m = step_fn(
                        params, opt_state, jnp.int32(5 + s), batch)
                jax.block_until_ready(m["loss"])
                dt = time.perf_counter() - t0
                best = max(best, steps * source.frames_per_batch / dt)
            mode = "pipelined" if pipelined else "sync"
            fps[mode] = best
            row(f"pipeline_{mode}_{env_name}",
                steps * tc.unroll_length * tc.batch_size / best * 1e6 / steps,
                f"{best:.0f}fps")
        row(f"pipeline_speedup_{env_name}", 0.0,
            f"{fps['pipelined'] / fps['sync']:.3f}x")


def _train_catch(mode, *, steps, threshold=0.05, window=50, seed=0,
                 replay_ratio=1.0, capacity=256, env_name="catch"):
    """One replay arm: train on catch (or gridworld), tracking the running
    mean of reward_per_step. Returns (fps over fresh env frames,
    frames at which the threshold was first sustained or None,
    final running-mean reward, fresh frames per batch)."""
    import collections
    import dataclasses

    from repro.configs.atari_impala import small_train
    from repro.core import learner as L
    from repro.core import replay as replay_lib
    from repro.core.sources import DeviceSource, ReplaySource
    from repro.envs import catch, gridworld

    env = {"catch": catch, "gridworld": gridworld}[env_name].make()
    tc = small_train(unroll_length=20, batch_size=32, learning_rate=2e-3,
                     total_steps=steps)
    if mode != "off":
        tc = dataclasses.replace(tc, clear_policy_cost=0.01,
                                 clear_value_cost=0.005)
    from repro.models.convnet import init_agent, minatar_net
    init_fn, apply_fn = minatar_net(env.obs_shape, env.num_actions)
    params, _ = init_agent(init_fn, jax.random.PRNGKey(seed))
    from repro.optim import make_optimizer
    opt = make_optimizer(tc)
    opt_state = opt.init(params)
    step_fn = jax.jit(L.make_train_step(apply_fn, opt, tc))

    source = DeviceSource.for_env(
        env, apply_fn, unroll_length=tc.unroll_length,
        batch_size=tc.batch_size, key=jax.random.PRNGKey(seed + 1),
        pipelined=True)
    if mode != "off":
        source = ReplaySource(source, replay_lib.make_buffer(mode, capacity),
                              replay_ratio=replay_ratio, seed=seed,
                              value_fn=jax.jit(
                                  lambda p, obs: apply_fn(p, obs).baseline))
    feedback = getattr(source, "on_learner_metrics", None)

    rewards = collections.deque(maxlen=window)
    solved_frames = None
    source.start(params)
    try:
        # one step outside the clock to absorb compilation
        batch = source.next_batch(params)
        params, opt_state, m = step_fn(params, opt_state, jnp.int32(0),
                                       batch)
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for s in range(1, steps):
            batch = source.next_batch(params)
            params, opt_state, m = step_fn(params, opt_state, jnp.int32(s),
                                           batch)
            if feedback is not None:
                feedback(s, m)
            rewards.append(float(m["reward_per_step"]))
            if (solved_frames is None and len(rewards) == window
                    and np.mean(rewards) >= threshold):
                solved_frames = (s + 1) * source.frames_per_batch
        jax.block_until_ready(m["loss"])
        dt = time.perf_counter() - t0
    finally:
        source.stop()
    fps = (steps - 1) * source.frames_per_batch / dt
    return (fps, solved_frames,
            float(np.mean(rewards)) if rewards else 0.0,
            source.frames_per_batch)


def bench_replay():
    """Off-policy replay on vs off: fresh-frame FPS and frames to the catch
    solve threshold (running-mean reward/step >= 0.05 over 50 steps;
    optimum is +0.1) for replay off / uniform / elite at replay_ratio 1:1,
    plus gridworld return at a fixed fresh-frame budget."""
    steps = 60 if SMALL else 1000
    window = 10 if SMALL else 50
    for mode in ("off", "uniform", "elite"):
        fps, solved, final, fpb = _train_catch(mode, steps=steps,
                                               window=window)
        solved_s = str(solved) if solved is not None else "never"
        row(f"replay_{mode}_catch", 1e6 / fps * fpb,
            f"{fps:.0f}fps solve_frames={solved_s} "
            f"final_reward={final:+.3f}")
    grid_steps = 30 if SMALL else 300
    for mode in ("off", "elite"):
        fps, _, final, fpb = _train_catch(mode, steps=grid_steps,
                                          window=window,
                                          threshold=float("inf"),
                                          env_name="gridworld")
        row(f"replay_{mode}_gridworld", 1e6 / fps * fpb,
            f"{fps:.0f}fps return_at_budget={final:+.3f}")


def bench_scaling(steps=40):
    """Data-parallel learner scaling: rollout+learn FPS vs mesh size for
    1/2/4/8 devices (weak scaling: 32 batch columns per device), plain and
    composed with the per-device-sliced replay buffer (``scaling_replay_*``
    rows — the sharded+replay FPS must stay close to sharded-only: the
    composition adds slot bookkeeping, not host-side concat/resharding).
    On CPU run under XLA_FLAGS=--xla_force_host_platform_device_count=8 —
    ``main`` sets it automatically when scaling is the SOLE suite requested
    (mixing it with other suites would skew their timings); otherwise the
    curve is truncated to the visible device count."""
    if SMALL:
        steps = 12
    from repro.configs.atari_impala import small_train
    from repro.core import learner as L
    from repro.core.replay import ShardedReplay
    from repro.core.sources import ReplaySource, ShardedDeviceSource
    from repro.distributed.sharding import RL_AGENT_RULES
    from repro.envs import catch
    from repro.launch.mesh import make_data_mesh
    from repro.models.convnet import init_agent, minatar_net
    from repro.optim import make_optimizer
    from jax.sharding import NamedSharding, PartitionSpec

    env = catch.make()
    n_dev = len(jax.devices())
    counts = [n for n in (1, 2, 4, 8) if n <= n_dev]
    per_device_batch = 32

    def arm(n, replay):
        mesh = make_data_mesh(n)
        tc = small_train(unroll_length=20, batch_size=per_device_batch * n)
        init_fn, apply_fn = minatar_net(env.obs_shape, env.num_actions)
        params, _ = init_agent(init_fn, jax.random.PRNGKey(0))
        params = jax.device_put(params, NamedSharding(mesh, PartitionSpec()))
        opt = make_optimizer(tc)
        opt_state = opt.init(params)
        step_fn = jax.jit(L.make_train_step(apply_fn, opt, tc, mesh=mesh,
                                            rules=RL_AGENT_RULES))
        source = ShardedDeviceSource.for_env(
            env, apply_fn, unroll_length=tc.unroll_length,
            batch_size=tc.batch_size, key=jax.random.PRNGKey(1), mesh=mesh,
            pipelined=True)
        if replay:
            source = ReplaySource(
                source, ShardedReplay("uniform", 16 * n, mesh),
                replay_ratio=0.25)
        m = None
        for s in range(4):  # warmup: compile per-device unrolls + step
            batch = source.next_batch(params)
            params, opt_state, m = step_fn(params, opt_state, jnp.int32(s),
                                           batch)
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for s in range(steps):
            batch = source.next_batch(params)
            params, opt_state, m = step_fn(params, opt_state,
                                           jnp.int32(4 + s), batch)
        jax.block_until_ready(m["loss"])
        dt = time.perf_counter() - t0
        source.stop()
        fps = steps * source.frames_per_batch / dt
        return fps, dt, tc.batch_size

    for n in counts:
        fps, dt, bsz = arm(n, replay=False)
        row(f"scaling_n{n}_catch", dt / steps * 1e6,
            f"{fps:.0f}fps {fps / n:.0f}fps/dev B={bsz}")
        fps_r, dt_r, _ = arm(n, replay=True)
        row(f"scaling_replay_n{n}_catch", dt_r / steps * 1e6,
            f"{fps_r:.0f}fps {fps_r / max(fps, 1e-9) * 100:.0f}%_of_plain "
            f"ratio=0.25")


def bench_fps_host_loop(duration=6.0):
    """MonoBeast/PolyBeast host actor loop throughput (§4 FPS analogue)."""
    from repro.configs.atari_impala import small_train
    from repro.core.actor_pool import ActorPool, start_inference_thread
    from repro.core.batcher import BatchingQueue, DynamicBatcher
    from repro.envs import catch
    from repro.envs.base import HostEnv
    from repro.models.convnet import init_agent, minatar_net
    env0 = catch.make()
    tc = small_train(unroll_length=20, batch_size=8, num_actors=8)
    init_fn, apply_fn = minatar_net(env0.obs_shape, env0.num_actions)
    params, _ = init_agent(init_fn, jax.random.PRNGKey(0))
    policy = jax.jit(lambda obs: apply_fn(params, obs).policy_logits)
    inference = DynamicBatcher(max_batch_size=8, timeout_ms=2)
    learner_queue = BatchingQueue(tc.batch_size, batch_dim=1, max_items=64)
    pool = ActorPool(lambda seed: HostEnv(env0, seed), tc.num_actors,
                     tc.unroll_length, inference, learner_queue)
    start_inference_thread(inference,
                           lambda obs: policy(jnp.asarray(obs)))
    pool.start()
    consumed = 0
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < duration:
        batch = learner_queue.get(timeout=1.0)
        if batch is not None:
            consumed += batch["reward"].size
    dt = time.perf_counter() - t0
    pool.stop()
    row("fps_host_loop_catch", dt * 1e6, f"{consumed/dt:.0f}fps")


def bench_dynamic_batcher():
    from repro.core.batcher import DynamicBatcher
    b = DynamicBatcher(max_batch_size=16, timeout_ms=1)
    n_req = 512
    done = threading.Event()

    def consumer():
        served = 0
        while served < n_req:
            got = b.get_batch(timeout=2.0)
            if got is None:
                break
            inputs, respond, n = got
            respond(inputs)
            served += n
        done.set()

    t = threading.Thread(target=consumer, daemon=True)
    x = np.zeros((84,), np.float32)
    t0 = time.perf_counter()
    t.start()
    threads = [threading.Thread(target=lambda: [b.compute(x)
                                                for _ in range(n_req // 16)])
               for _ in range(16)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    done.wait(timeout=5)
    dt = time.perf_counter() - t0
    row("dynamic_batcher_roundtrip", dt / n_req * 1e6,
        f"{n_req/dt:.0f}req/s")


def bench_attention():
    import dataclasses
    from repro.configs import get_reduced_config
    from repro.models import attention as A
    from repro.models.common import split_params
    cfg = dataclasses.replace(get_reduced_config("qwen3-32b"),
                              attn_chunk=128)
    params = split_params(A.attn_init(jax.random.PRNGKey(0), cfg, "attn"))[0]
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 512, cfg.d_model),
                          jnp.float32)
    pos = jnp.arange(512)
    for impl in ("xla", "xla_chunked", "xla_chunked_skip"):
        f = jax.jit(lambda x, impl=impl: A.attn_apply(
            params, x, cfg=cfg, kind="attn", positions=pos, impl=impl)[0])
        us = timeit(lambda: jax.block_until_ready(f(x)), n=10)
        row(f"attention_{impl}_S512", us, "")


def bench_generate():
    from repro.configs import get_reduced_config
    from repro.core import generate as G
    from repro.models import model as M
    cfg = get_reduced_config("qwen3-4b")
    params, _ = M.init(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (8, 15), 0,
                                cfg.vocab_size)

    def f():
        return jax.block_until_ready(
            G.generate(params, prompt, jax.random.PRNGKey(2), cfg=cfg,
                       num_steps=32)["tokens"])

    us = timeit(f, n=5)
    row("generate_B8_P15_N32", us, f"{8*32/(us/1e6):.0f}tok/s")


def bench_ssd_chunk():
    from repro.kernels import ops, ref
    rng = np.random.default_rng(0)
    bh, l, n, p = 8, 128, 64, 64
    c = jnp.asarray(rng.normal(0, 1, (bh, l, n)), jnp.float32)
    b = jnp.asarray(rng.normal(0, 1, (bh, l, n)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (bh, l, p)), jnp.float32)
    da = jnp.asarray(-rng.random((bh, l, 1)) * 0.1, jnp.float32)
    h = jnp.asarray(rng.normal(0, 1, (bh, p, n)), jnp.float32)
    f = jax.jit(lambda *a: ref.ref_ssd_chunk(*a))
    us = timeit(lambda: jax.block_until_ready(f(c, b, x, da, h)[0]), n=10)
    row("ssd_chunk_jnp_BH8_L128", us, "")
    g = jax.jit(lambda *a: ops.ssd_chunk(*a))
    us = timeit(lambda: jax.block_until_ready(g(c, b, x, da, h)[0]), n=3)
    row("ssd_chunk_pallas_interp", us, "interpret-mode")


def bench_serving():
    """Continuous vs static batching on the DecodeSession server under
    Poisson arrivals with heavy-tail (lognormal) prompt/generation lengths
    — the workload where per-step admission pays: static batching holds
    freed slots hostage to the longest generation in the batch. Per-request
    keys are pinned so both policies serve IDENTICAL token streams; rows
    report request-latency p50/p99 (us) and sustained generated tok/s."""
    from repro.configs import get_reduced_config
    from repro.launch.serve import Server
    from repro.models import model as M

    cfg = get_reduced_config("qwen3-4b")
    params, _ = M.init(jax.random.PRNGKey(0), cfg)
    n_req = 12 if SMALL else 48
    max_batch = 4
    max_len = 24 if SMALL else 64
    rng = np.random.default_rng(0)
    plens = np.clip(rng.lognormal(1.0, 0.8, n_req).astype(int) + 1,
                    1, max_len // 2)
    glens = np.clip(rng.lognormal(1.2, 1.0, n_req).astype(int) + 1,
                    1, max_len // 2)
    gaps = rng.exponential(0.005, n_req)         # Poisson arrivals
    prompts = [rng.integers(0, cfg.vocab_size, size=int(p)) for p in plens]
    keys = [np.asarray(jax.random.PRNGKey(1000 + i)) for i in range(n_req)]

    def run(policy):
        server = Server(cfg, params, max_batch=max_batch, max_len=max_len,
                        policy=policy).start()
        t0 = time.perf_counter()
        handles = []
        for i in range(n_req):
            time.sleep(gaps[i])
            handles.append(server.submit(prompts[i],
                                         max_tokens=int(glens[i]),
                                         key=keys[i]))
        tokens = sum(h.result(timeout=600).shape[0] - h.prompt.shape[0]
                     for h in handles)
        dt = time.perf_counter() - t0
        lat = np.asarray([h.t_done - h.t_submit for h in handles])
        server.stop()
        return lat, tokens / dt, server.steps

    run("continuous")   # warmup: pay the per-bucket prefill compiles once
    stats = {}
    for policy in ("continuous", "static"):
        lat, tps, steps = run(policy)
        stats[policy] = tps
        for q, v in (("p50", np.quantile(lat, 0.5)),
                     ("p99", np.quantile(lat, 0.99))):
            row(f"serving_{policy}_{q}", v * 1e6,
                f"{tps:.1f}tok/s steps={steps}")
    row("serving_speedup", 0.0,
        f"continuous/static={stats['continuous']/stats['static']:.2f}x")


def bench_kernels():
    """xla reference vs Pallas kernel per hot-path op (flash attention,
    decode attention, SSD chunk, V-trace) at a small and a paper-ish shape,
    with achieved-vs-roofline accounting from
    ``launch.roofline.kernel_roofline`` at the measured dims. On CPU the
    kernels execute in interpret mode (see kernels/compat.py), so
    ``of_roofline`` documents interpreter overhead only; on a TPU the same
    rows measure real kernel efficiency against the analytic roofline."""
    from repro.core.vtrace import vtrace_from_importance_weights
    from repro.kernels import ops, ref
    from repro.launch.roofline import kernel_roofline

    rng = np.random.default_rng(0)

    def norm(*shape):
        return jnp.asarray(rng.normal(0, 1, shape), jnp.float32)

    def versus(name, ref_call, kern_call, kern, dims, n_ref=10, n_kern=2):
        us_ref = timeit(lambda: jax.block_until_ready(ref_call()), n=n_ref)
        row(f"{name}_xla", us_ref, "")
        us_k = timeit(lambda: jax.block_until_ready(kern_call()), n=n_kern,
                      warmup=1)
        r = kernel_roofline(kern, dtype_bytes=4, **dims)
        row(f"{name}_kernel", us_k,
            f"vs_xla={us_ref / us_k:.3f}x "
            f"roofline_us={r['roofline_s'] * 1e6:.2f} "
            f"of_roofline={100 * r['roofline_s'] * 1e6 / us_k:.3f}% "
            f"bound={r['bound']}")

    s_big = 256 if SMALL else 2048
    for tag, b, h, kh, s, hd in (("small", 2, 4, 2, 128, 32),
                                 ("paperish", 1, 8, 4, s_big, 64)):
        q, k, v = norm(b, h, s, hd), norm(b, kh, s, hd), norm(b, kh, s, hd)
        blk = min(128, s)
        fx = jax.jit(lambda q, k, v: ref.ref_flash_attention(q, k, v))
        fk = jax.jit(lambda q, k, v: ops.flash_attention(
            q, k, v, block_q=blk, block_k=blk))
        versus(f"flash_{tag}_S{s}", lambda: fx(q, k, v),
               lambda: fk(q, k, v), "flash_attention",
               dict(b=b, h=h, kh=kh, s=s, hd=hd, window=0))

    cap_big = 512 if SMALL else 4096
    for tag, b, h, kh, cap, hd in (("small", 8, 4, 2, 128, 32),
                                   ("paperish", 32, 8, 4, cap_big, 64)):
        q, k, v = norm(b, h, hd), norm(b, kh, cap, hd), norm(b, kh, cap, hd)
        slot = jnp.arange(cap, dtype=jnp.int32)
        pos = jnp.int32(cap - 1)
        dx = jax.jit(lambda q, k, v: ref.ref_decode_attention(
            q, k, v, slot, pos))
        dk = jax.jit(lambda q, k, v: ops.decode_attention(
            q, k, v, slot, pos, block_k=min(128, cap)))
        versus(f"decode_{tag}_T{cap}", lambda: dx(q, k, v),
               lambda: dk(q, k, v), "decode_attention",
               dict(b=b, h=h, kh=kh, s=cap, hd=hd), n_kern=3)

    for tag, bh, l, n, p in (("small", 4, 64, 32, 32),
                             ("paperish", 8 if SMALL else 64,
                              128 if SMALL else 256, 64, 64)):
        c, bm, x = norm(bh, l, n), norm(bh, l, n), norm(bh, l, p)
        da = jnp.asarray(-rng.random((bh, l, 1)) * 0.1, jnp.float32)
        hp = norm(bh, p, n)
        sx = jax.jit(ref.ref_ssd_chunk)
        sk = jax.jit(lambda *a: ops.ssd_chunk(*a))
        versus(f"ssd_{tag}_L{l}", lambda: sx(c, bm, x, da, hp)[0],
               lambda: sk(c, bm, x, da, hp)[0], "ssd_chunk",
               dict(bh=bh, l=l, n=n, p=p))

    t, b = 80, 256
    args = [norm(t, b) for _ in range(4)] + [norm(b)]
    vx = jax.jit(vtrace_from_importance_weights)
    vk = jax.jit(ops.vtrace_from_importance_weights_kernel)
    versus(f"vtrace_T{t}_B{b}", lambda: vx(*args), lambda: vk(*args),
           "vtrace", dict(t=t, b=b), n_kern=3)


def roofline_table():
    """Print the §Roofline table from the dry-run artifacts (preferring the
    post-§Perf optimized sweep)."""
    files = (sorted(glob.glob("experiments/dryrun_optimized/*.json"))
             or sorted(glob.glob("experiments/dryrun/*.json"))
             or sorted(glob.glob("experiments/dryrun_baseline/*.json")))
    if not files:
        print("# roofline: no dry-run artifacts; run "
              "`python -m repro.launch.dryrun --all` first")
        return
    print("# arch,shape,mesh,rules,compute_s,memory_s,collective_s,"
          "bottleneck,useful_ratio,mem_GiB")
    for f in files:
        d = json.load(open(f))
        r = d["roofline"]
        print(f"roofline,{d['arch']},{d['shape']},{d['mesh']},{d['rules']},"
              f"{r['compute_s']:.2e},{r['memory_s']:.2e},"
              f"{r['collective_s']:.2e},{r['bottleneck']},"
              f"{r['useful_ratio']:.2f},"
              f"{d['memory']['per_device_total']/2**30:.2f}")


_SUITES = {
    "vtrace": bench_vtrace,
    "learner": bench_learner_step,
    "fps": bench_fps_on_device,
    "pipeline": bench_pipeline,
    "replay": bench_replay,
    "scaling": bench_scaling,
    "host_loop": bench_fps_host_loop,
    "batcher": bench_dynamic_batcher,
    "attention": bench_attention,
    "generate": bench_generate,
    "serving": bench_serving,
    "ssd": bench_ssd_chunk,
    "kernels": bench_kernels,
    "roofline": roofline_table,
}


def main(argv=None) -> None:
    import argparse
    import os
    p = argparse.ArgumentParser()
    p.add_argument("--suite", choices=["all"] + sorted(_SUITES),
                   action="append", default=None,
                   help="suite to run; repeatable (default: everything)")
    p.add_argument("--small", action="store_true",
                   help="CI-smoke scale (short training arms)")
    p.add_argument("--out-dir", default=".",
                   help="where BENCH_<suite>.json artifacts are written")
    args = p.parse_args(argv)
    global SMALL
    SMALL = args.small
    os.makedirs(args.out_dir, exist_ok=True)
    suites = args.suite or ["all"]
    if "all" in suites:
        suites = list(_SUITES)
    if (suites == ["scaling"]
            and "--xla_force_host_platform_device_count"
            not in os.environ.get("XLA_FLAGS", "")):
        # must land before jax initialises its backend (no device query has
        # happened yet — suites run after this point). Only when scaling is
        # the SOLE suite: forcing 8 CPU devices would skew every other
        # suite's timings in the same process (run scaling standalone to
        # get the full 1/2/4/8 curve).
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " --xla_force_host_platform_device_count=8")
    print("name,us_per_call,derived")
    for name in suites:
        _RESULTS.clear()
        _SUITES[name]()
        path = os.path.join(args.out_dir, f"BENCH_{name}.json")
        with open(path, "w") as f:
            json.dump({"suite": name, "small": SMALL,
                       "backend": jax.default_backend(),
                       "devices": jax.device_count(),
                       "rows": list(_RESULTS)}, f, indent=1)
        print(f"# wrote {path}", flush=True)


if __name__ == "__main__":
    main()
