"""DeepSeek-Coder-33B [dense] — llama-arch. [arXiv:2401.14196]

62L, d_model=7168, 56 heads (GQA kv=8, head_dim=128), d_ff=19200,
vocab=32256. RoPE theta 1e5 (DeepSeek-Coder long-context base).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    arch_type="dense",
    source="arXiv:2401.14196 (DeepSeek-Coder)",
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32256,
    block_pattern=(("attn", "swiglu"),),
    num_groups=62,
    rope_theta=1e5,
    tie_embeddings=False,
)
