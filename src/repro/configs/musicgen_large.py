"""MusicGen-Large [audio] — decoder-only transformer over EnCodec tokens.
[arXiv:2306.05284]

48L, d_model=2048, 32 heads (kv=32, head_dim=64), d_ff=8192 (GELU),
vocab=2048 (EnCodec codebook size). LayerNorm + sinusoidal positions.
The EnCodec tokenizer is the stubbed modality frontend: input_specs()
provides token ids; the 4-codebook delay interleave is flattened to a
single stream (DESIGN.md §5/§10).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    source="arXiv:2306.05284 (MusicGen)",
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    block_pattern=(("attn", "gelu"),),
    num_groups=48,
    norm="layernorm",
    pos_emb="sinusoidal",
    tie_embeddings=False,
)
