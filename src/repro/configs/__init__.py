"""Config registry: ``get_config(arch)`` returns the full published config,
``get_reduced_config(arch)`` a CPU-smoke variant of the same family
(<=2 effective layer repeats, d_model<=512, <=4 experts)."""

from __future__ import annotations

import dataclasses

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig, TrainConfig

from repro.configs import (deepseek_coder_33b, gemma2_27b, granite_moe_1b,
                           llama32_vision_90b, mixtral_8x7b, musicgen_large,
                           qwen3_32b, qwen3_4b, xlstm_125m, zamba2_2_7b)

_REGISTRY = {
    "qwen3-32b": qwen3_32b.CONFIG,
    "xlstm-125m": xlstm_125m.CONFIG,
    "musicgen-large": musicgen_large.CONFIG,
    "llama-3.2-vision-90b": llama32_vision_90b.CONFIG,
    "deepseek-coder-33b": deepseek_coder_33b.CONFIG,
    "zamba2-2.7b": zamba2_2_7b.CONFIG,
    "qwen3-4b": qwen3_4b.CONFIG,
    "granite-moe-1b-a400m": granite_moe_1b.CONFIG,
    "gemma2-27b": gemma2_27b.CONFIG,
    "mixtral-8x7b": mixtral_8x7b.CONFIG,
}

ARCHS = tuple(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


# per-arch overrides that don't follow the generic reduction
_REDUCED_PATTERN = {
    "llama-3.2-vision-90b": ((("attn", "swiglu"), ("xattn", "swiglu")), 1),
    "zamba2-2.7b": ((("mamba", "none"),) * 2, 1),
    "xlstm-125m": ((("mlstm", "none"), ("slstm", "none")), 1),
    "gemma2-27b": ((("local_attn", "geglu"), ("attn", "geglu")), 1),
}


def get_reduced_config(name: str) -> ModelConfig:
    """Small same-family variant for CPU smoke tests."""
    cfg = get_config(name)
    pattern, groups = _REDUCED_PATTERN.get(
        name, (cfg.block_pattern, max(1, 2 // len(cfg.block_pattern))))
    kv = min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4
    overrides = dict(
        name=cfg.name + "-reduced",
        d_model=256,
        num_heads=4,
        num_kv_heads=kv,
        head_dim=64,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=512,
        block_pattern=pattern,
        num_groups=groups,
        sliding_window=32,
        attn_chunk=64,
        ssm_chunk=16,
        xlstm_chunk=16,
        vision_seq=16 if cfg.vision_seq else 0,
        long_context_window=64,
        dtype="float32",
        remat=False,
    )
    if cfg.num_experts:
        # capacity_factor 4.0 => dropless at smoke scale: capacity-based
        # token dropping is batch-composition dependent, so prefill-vs-
        # decode consistency checks need it off (DESIGN.md §10).
        overrides.update(num_experts=4,
                         num_experts_per_tok=min(cfg.num_experts_per_tok, 2),
                         moe_d_ff=128, capacity_factor=4.0)
    if cfg.shared_attn_every:
        overrides["shared_attn_every"] = 2
    if cfg.ssm_state:
        overrides.update(ssm_state=16, ssm_head_dim=32)
    if cfg.attn_scale is not None:
        overrides["attn_scale"] = (256 / 4) ** -0.5
    return dataclasses.replace(cfg, **overrides)


__all__ = ["ARCHS", "INPUT_SHAPES", "InputShape", "ModelConfig",
           "TrainConfig", "get_config", "get_reduced_config"]
