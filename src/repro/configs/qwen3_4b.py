"""Qwen3-4B [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B family card]

36L, d_model=2560, 32 heads (GQA kv=8, head_dim=128), d_ff=9728,
vocab=151936. Tied embeddings, RoPE theta 1e6.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    arch_type="dense",
    source="hf:Qwen/Qwen3-8B (family); Qwen3 technical report",
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    block_pattern=(("attn", "swiglu"),),
    num_groups=36,
    use_qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=True,
)
