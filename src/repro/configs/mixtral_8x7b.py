"""Mixtral 8x7B [moe] — 8 experts top-2, sliding-window attention.
[arXiv:2401.04088]

32L, d_model=4096, 32 heads (GQA kv=8, head_dim=128), expert d_ff=14336,
vocab=32000, window 4096, RoPE theta 1e6.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    arch_type="moe",
    source="arXiv:2401.04088 (Mixtral of Experts)",
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    block_pattern=(("swa_attn", "moe"),),
    num_groups=32,
    sliding_window=4096,
    num_experts=8,
    num_experts_per_tok=2,
    moe_d_ff=14336,
    rope_theta=1e6,
    tie_embeddings=False,
)
