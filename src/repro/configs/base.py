"""Configuration dataclasses for JAXBeast.

A ``ModelConfig`` fully describes an agent/decoder architecture. The decoder
is organised as ``num_groups`` repetitions of a *super-block*: a tuple of
``(mixer, ffn)`` layer specs scanned over with ``jax.lax.scan`` (stacked
params), so HLO size is independent of depth.

Mixer kinds:   attn | local_attn | swa_attn | xattn | mamba | mlstm | slstm
FFN kinds:     swiglu | geglu | gelu | moe | none
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

LayerSpec = Tuple[str, str]  # (mixer, ffn)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense | ssm | moe | hybrid | vlm | audio
    source: str                         # citation for the architecture numbers

    d_model: int
    num_heads: int
    num_kv_heads: int
    vocab_size: int
    d_ff: int

    block_pattern: Tuple[LayerSpec, ...]
    num_groups: int                     # scan length; layers = num_groups * len(block_pattern)

    head_dim: int = 0                   # 0 -> d_model // num_heads

    # --- attention options -------------------------------------------------
    use_qk_norm: bool = False
    pos_emb: str = "rope"               # rope | sinusoidal | none
    rope_theta: float = 1e4
    sliding_window: int = 4096          # for local_attn / swa_attn mixers
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    attn_scale: Optional[float] = None  # None -> 1/sqrt(head_dim)

    # --- norms / residual ---------------------------------------------------
    norm: str = "rmsnorm"               # rmsnorm | layernorm
    norm_eps: float = 1e-6
    sandwich_norm: bool = False         # gemma2 pre+post sublayer norms
    embed_scale: bool = False           # gemma: scale embeddings by sqrt(d)
    tie_embeddings: bool = False

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3

    # --- SSM (Mamba2) ---------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # --- xLSTM ----------------------------------------------------------------
    xlstm_chunk: int = 64

    # --- zamba-style shared global block --------------------------------------
    shared_attn_every: int = 0          # >0: shared attn+mlp block after each group

    # --- VLM ------------------------------------------------------------------
    vision_seq: int = 0                 # stub patch-embedding sequence length

    # --- RL heads ---------------------------------------------------------------
    baseline_head: bool = True          # value head for IMPALA

    # --- numerics / impl ---------------------------------------------------------
    dtype: str = "bfloat16"
    # auto | xla | xla_chunked | xla_chunked_skip | kernel
    # ("pallas" is the legacy spelling of "kernel")
    attn_impl: str = "auto"
    attn_chunk: int = 1024
    ssd_impl: str = "xla"               # xla | kernel (mamba chunk scan)
    remat: bool = True
    # serving adaptation for long_500k on pure full-attention archs (see DESIGN.md)
    long_context_window: int = 8192

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def num_layers(self) -> int:
        return self.num_groups * len(self.block_pattern)

    @property
    def is_recurrent(self) -> bool:
        return any(m in ("mamba", "mlstm", "slstm") for m, _ in self.block_pattern)

    @property
    def is_subquadratic(self) -> bool:
        """True if no mixer needs an unbounded KV cache."""
        for mixer, _ in self.block_pattern:
            if mixer in ("attn", "xattn"):
                return False
        if self.shared_attn_every:
            return False  # shared attn is full unless long-context windowed
        return True

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + heads)."""
        d, hd = self.d_model, self.resolved_head_dim
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d
        for mixer, ffn in self.block_pattern * self.num_groups:
            if mixer in ("attn", "local_attn", "swa_attn", "xattn"):
                n += d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                n += self.num_heads * hd * d
                n += d  # norm
                if self.use_qk_norm:
                    n += 2 * hd
            elif mixer == "mamba":
                d_in = self.ssm_expand * d
                nheads = d_in // self.ssm_head_dim
                n += d * (2 * d_in + 2 * self.ssm_state + nheads)  # in_proj(zx) + B,C, dt
                n += d_in * d + d + 2 * nheads + d_in * self.ssm_conv_width
            elif mixer in ("mlstm", "slstm"):
                d_in = 2 * d
                n += d * d_in * 2 + d_in * d + 3 * d * self.num_heads + d
            if ffn == "moe":
                n += self.num_experts * 3 * d * self.moe_d_ff + d * self.num_experts + d
            elif ffn in ("swiglu", "geglu"):
                n += 3 * d * self.d_ff + d
            elif ffn == "gelu":
                n += 2 * d * self.d_ff + d
        if self.shared_attn_every:
            n += d * self.num_heads * hd * 2 + 2 * d * self.num_kv_heads * hd
            n += 3 * d * self.d_ff
        if self.baseline_head:
            n += d
        n += d  # final norm
        return n


@dataclasses.dataclass(frozen=True)
class ImplContext:
    """Kernel-implementation context, resolved ONCE at the CLI boundary.

    Collapses the per-call ``attn_impl=`` / ``ssd_impl=`` kwarg threading:
    drivers fold the CLI flags into the ``ModelConfig`` via ``apply`` and
    every downstream path (learner factories, generate/DecodeSession,
    serving, spec builders) reads ``cfg.attn_impl`` / ``cfg.ssd_impl``.
    ``None`` fields keep the config's existing choice.
    """
    attn: Optional[str] = None   # auto | xla | xla_chunked | xla_chunked_skip | kernel
    ssd: Optional[str] = None    # xla | kernel

    @classmethod
    def from_args(cls, args) -> "ImplContext":
        """Build from an argparse namespace carrying --attn-impl/--ssd-impl."""
        return cls(attn=getattr(args, "attn_impl", None),
                   ssd=getattr(args, "ssd_impl", None))

    def apply(self, cfg: "ModelConfig") -> "ModelConfig":
        over = {}
        if self.attn:
            over["attn_impl"] = self.attn
        if self.ssd:
            over["ssd_impl"] = self.ssd
        return dataclasses.replace(cfg, **over) if over else cfg


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """IMPALA learner/optimizer hyperparameters (defaults: IMPALA Table G.1)."""
    optimizer: str = "rmsprop"
    learning_rate: float = 6e-4
    rmsprop_eps: float = 0.01
    rmsprop_decay: float = 0.99
    rmsprop_momentum: float = 0.0
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 40.0             # global-norm clip, IMPALA default
    lr_schedule: str = "linear"         # linear anneal to 0, IMPALA default
    total_steps: int = 100_000
    warmup_steps: int = 0

    # IMPALA loss weights (Table G.1)
    baseline_cost: float = 0.5
    entropy_cost: float = 0.01
    discount: float = 0.99
    vtrace_rho_clip: float = 1.0
    vtrace_c_clip: float = 1.0

    # CLEAR cloning costs on replayed rows (active only when the batch
    # carries an is_replay mask — i.e. behind a ReplaySource)
    clear_policy_cost: float = 0.0
    clear_value_cost: float = 0.0

    unroll_length: int = 80
    batch_size: int = 32
    num_actors: int = 48

    seed: int = 0
