"""The paper's own experimental config (§4): IMPALA deep ResNet agent,
Atari preprocessing shapes (84x84, 4-frame stack, 18 actions), and the
IMPALA Table G.1 hyperparameters used by TorchBeast.

ALE itself is not available in this container; the faithful agent/learner
path is exercised on the JAX-native envs (Catch / MinAtar-style gridworld),
exactly the adaptation the paper demonstrates in Figs. 1-2 (DESIGN.md §10).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import TrainConfig

OBS_SHAPE = (84, 84, 4)   # warped, 4-frame-stacked Atari
NUM_ACTIONS = 18          # full ALE action set

TRAIN = TrainConfig(
    optimizer="rmsprop",
    learning_rate=6e-4,      # IMPALA Table G.1 (0.0006)
    rmsprop_eps=0.01,
    rmsprop_decay=0.99,
    rmsprop_momentum=0.0,
    grad_clip=40.0,
    lr_schedule="linear",
    baseline_cost=0.5,
    entropy_cost=0.01,
    discount=0.99,
    unroll_length=80,
    batch_size=32,
    num_actors=48,           # paper: 48 environments
    total_steps=50_000_000 // (80 * 32),  # 200M frames / action-rep 4
)


def small_train(**overrides) -> TrainConfig:
    """CPU-scale variant for tests/examples."""
    base = dataclasses.replace(
        TRAIN, unroll_length=20, batch_size=8, num_actors=8,
        total_steps=2000, learning_rate=1e-3)
    return dataclasses.replace(base, **overrides)
