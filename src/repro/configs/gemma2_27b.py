"""Gemma-2 27B [dense] — local+global alternating attention, logit softcaps,
sandwich norms, GeGLU. [arXiv:2408.00118]

46L, d_model=4608, 32 heads (GQA kv=16, head_dim=128), d_ff=36864,
vocab=256000. Query scale = (d_model/num_heads)^-0.5 = 144^-0.5 (not
head_dim). Sliding window 4096 on local layers; tied embeddings with
sqrt(d) embedding scale.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    arch_type="dense",
    source="arXiv:2408.00118 (Gemma 2)",
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    block_pattern=(("local_attn", "geglu"), ("attn", "geglu")),
    num_groups=23,
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    attn_scale=(4608 / 32) ** -0.5,
    sandwich_norm=True,
    embed_scale=True,
    tie_embeddings=True,
)
