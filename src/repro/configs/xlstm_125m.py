"""xLSTM-125M [ssm] — alternating mLSTM + sLSTM blocks. [arXiv:2405.04517]

12L, d_model=768, 4 heads, d_ff=0 (projections live inside the xLSTM
blocks), vocab=50304. No position embedding (recurrence carries order).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    arch_type="ssm",
    source="arXiv:2405.04517 (xLSTM)",
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=(("mlstm", "none"), ("slstm", "none")),
    num_groups=6,
    pos_emb="none",
    tie_embeddings=True,
)
