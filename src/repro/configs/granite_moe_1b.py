"""Granite-3.0 1B-A400M [moe] — 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]

24L, d_model=1024, 16 heads (GQA kv=8, head_dim=64), expert d_ff=512,
vocab=49155. Tied embeddings.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    arch_type="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    block_pattern=(("attn", "moe"),),
    num_groups=24,
    num_experts=32,
    num_experts_per_tok=8,
    moe_d_ff=512,
    tie_embeddings=True,
)
