"""Zamba2-2.7B [hybrid] — Mamba2 backbone + weight-shared attention block.
[arXiv:2411.15242]

54 Mamba2 blocks, d_model=2560, ssm_state=64; one shared attention+MLP
block (32 heads, d_ff=10240) applied after every 6 Mamba blocks (same
weights each time — Zamba's global memory block). vocab=32000.
Per-invocation LoRA adapters on the shared block are omitted (DESIGN.md §10).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    source="arXiv:2411.15242 (Zamba2)",
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    block_pattern=(("mamba", "none"),) * 6,
    num_groups=9,
    shared_attn_every=6,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    tie_embeddings=True,
)
