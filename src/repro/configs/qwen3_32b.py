"""Qwen3-32B [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B family card]

64L, d_model=5120, 64 heads (GQA kv=8, head_dim=128), d_ff=25600,
vocab=151936. Untied embeddings, RoPE theta 1e6.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    arch_type="dense",
    source="hf:Qwen/Qwen3-8B (family); Qwen3 technical report",
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    block_pattern=(("attn", "swiglu"),),
    num_groups=64,
    use_qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=False,
)
