"""Llama-3.2-Vision-90B [vlm] — self-attention decoder with cross-attention
image layers every 5th layer. [hf:meta-llama/Llama-3.2-11B-Vision family]

100L (80 self + 20 cross), d_model=8192, 64 heads (GQA kv=8, head_dim=128),
d_ff=28672, vocab=128256. The ViT vision encoder + projector are stubbed:
input_specs() provides precomputed patch embeddings (B, 6144, d_model)
consumed by the cross-attention layers (DESIGN.md §5).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    arch_type="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision (family card)",
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    block_pattern=(
        ("attn", "swiglu"), ("attn", "swiglu"), ("attn", "swiglu"),
        ("attn", "swiglu"), ("xattn", "swiglu"),
    ),
    num_groups=20,
    vision_seq=6144,  # ~4x1601 patches rounded to the 1024-chunk grid (DESIGN.md §10)
    rope_theta=5e5,
    tie_embeddings=False,
)
