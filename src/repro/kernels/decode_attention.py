"""Pallas TPU decode attention (flash-decode style).

One new query token per sequence attends to a KV cache of length S_cache.
Grid: (batch * kv_heads, num_kv_blocks); each instance processes all
``group`` = H/K query heads that share one kv head, so the q tile is
(group, hd) — MXU-friendly for GQA (group x bk matmuls) — and the KV cache
is read exactly once.

Supports position-validity masking (ring-buffer sliding-window caches pass
per-slot positions computed by the wrapper) and logit softcap.

Layout: q (B, H, hd); k, v (B, K, S, hd); slot_pos (S,) or (B, S) int32;
pos scalar or (B,). Per-row positions serve the continuous-batching
decode path, where every batch slot sits at its own sequence position;
scalar inputs are broadcast (the lockstep `generate` fast path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.ops import NEG_INF


def _kernel(pos_ref, q_ref, k_ref, v_ref, slot_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale, softcap, window, bk,
            num_kv_blocks, kheads):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                   # (g, hd)
    k = k_ref[0].astype(jnp.float32)                   # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    pos = pos_ref[pl.program_id(0) // kheads]          # this row's position
    slot_pos = slot_ref[...]                           # (1, bk) int32

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    valid = jnp.logical_and(slot_pos >= 0, slot_pos <= pos)
    if window:
        valid = jnp.logical_and(valid, pos - slot_pos < window)
    s = jnp.where(valid, s, NEG_INF)                   # (g, bk) via broadcast

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "softcap", "window", "block_k", "interpret"))
def decode_attention(q, k, v, slot_pos, pos, *, scale=None, softcap=0.0,
                     window=0, block_k=128, interpret=False):
    """q: (B,H,hd); k,v: (B,K,S,hd); slot_pos: (S,) or (B,S) int32 position
    held by each cache slot (-1 = empty); pos: scalar or (B,) int32 current
    position per sequence. Returns (B,H,hd)."""
    b, h, hd = q.shape
    _, kheads, s, _ = k.shape
    assert h % kheads == 0
    group = h // kheads
    bk = min(block_k, s)
    assert s % bk == 0
    nk = s // bk
    if scale is None:
        scale = hd ** -0.5

    qf = q.reshape(b * kheads, group, hd)
    kf = k.reshape(b * kheads, s, hd)
    vf = v.reshape(b * kheads, s, hd)
    slot2d = jnp.broadcast_to(jnp.asarray(slot_pos, jnp.int32).reshape(-1, s),
                              (b, s))
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))

    kernel = functools.partial(_kernel, scale=scale, softcap=softcap,
                               window=window, bk=bk, num_kv_blocks=nk,
                               kheads=kheads)

    out = pl.pallas_call(
        kernel,
        grid=(b * kheads, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),            # pos
            pl.BlockSpec((1, group, hd), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk), lambda bh, ki: (bh // kheads, ki)),
        ],
        out_specs=pl.BlockSpec((1, group, hd), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kheads, group, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(pos_arr, qf, kf, vf, slot2d)
    return out.reshape(b, h, hd)
