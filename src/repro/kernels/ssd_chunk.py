"""Pallas TPU kernel for one Mamba2 SSD chunk (the SSM hot loop).

Computes, for a single (batch, head) program instance over one chunk of
length L with state size N and head dim P:

  acs   = cumsum(da)                              (L,)
  Lmat  = exp(segsum(da))  (lower-tri)            (L, L)
  y     = ((C B^T) ∘ Lmat) X  +  (C h_prev) ∘ exp(acs)    (L, P)
  h_new = h_prev * exp(acs[-1]) + (B * exp(acs[-1]-acs))^T X   (P-major)

All three contractions are (L,N)x(N,L), (L,L)x(L,P), (L,N)x(N,P) matmuls —
MXU shaped for L in {128, 256}, N = P = 64/128. The inter-chunk recurrence
(h carry) stays outside (lax.scan in models/mamba.py); this kernel is the
body that dominates FLOPs. TPU adaptation of the Mamba2 CUDA kernel per
DESIGN.md §8 — matmul form, not a sequential scan.

Layouts: c, b (BH, L, N); xdt (BH, L, P); da (BH, L, 1); h_prev (BH, P, N).
Returns (y (BH, L, P), h_new (BH, P, N)).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams


def _kernel(c_ref, b_ref, x_ref, da_ref, h_ref, y_ref, hnew_ref, *, l, n, p):
    c = c_ref[0].astype(jnp.float32)          # (L, N)
    b = b_ref[0].astype(jnp.float32)          # (L, N)
    x = x_ref[0].astype(jnp.float32)          # (L, P)
    da = da_ref[0].astype(jnp.float32)        # (L, 1)
    h_prev = h_ref[0].astype(jnp.float32)     # (P, N)

    acs = jnp.cumsum(da[:, 0])                # (L,)
    # segsum: seg[i, j] = acs[i] - acs[j], masked lower-tri (incl diag)
    seg = acs[:, None] - acs[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    lmat = jnp.where(jj <= ii, jnp.exp(seg), 0.0)   # (L, L)

    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    scores = scores * lmat                           # (L, L)
    y_diag = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    # off-diagonal: contribution of the incoming state
    ch = jax.lax.dot_general(c, h_prev, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, P)
    y = y_diag + ch * jnp.exp(acs)[:, None]
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: h_new = h_prev * exp(acs[-1]) + X^T (B * w),  w_l =
    # exp(acs[-1] - acs_l)
    w = jnp.exp(acs[l - 1] - acs)[:, None]           # (L, 1)
    bw = b * w                                        # (L, N)
    xtb = jax.lax.dot_general(x, bw, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    hnew_ref[0] = (h_prev * jnp.exp(acs[l - 1]) + xtb).astype(hnew_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(c, b, xdt, da, h_prev, *, interpret=False):
    """c, b: (BH, L, N); xdt: (BH, L, P); da: (BH, L, 1) (<= 0);
    h_prev: (BH, P, N). Returns (y (BH, L, P), h_new (BH, P, N))."""
    bh, l, n = c.shape
    p = xdt.shape[-1]

    kernel = functools.partial(_kernel, l=l, n=n, p=p)
    return pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, l, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, l, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, l, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, l, 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, p, n), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, l, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, p, n), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, l, p), xdt.dtype),
            jax.ShapeDtypeStruct((bh, p, n), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(c, b, xdt, da, h_prev)
