"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ops import NEG_INF


def ref_flash_attention(q, k, v, *, scale=None, causal=True, window=0,
                        softcap=0.0):
    """q: (B,H,S,hd); k,v: (B,K,S,hd). Dense-softmax reference."""
    b, h, s, hd = q.shape
    kheads = k.shape[1]
    group = h // kheads
    if scale is None:
        scale = hd ** -0.5
    qg = q.reshape(b, kheads, group, s, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bkgqh,bkth->bkgqt", qg, kf) * scale
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= qpos - kpos < window
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgqt,bkth->bkgqh", p, vf)
    return o.reshape(b, h, s, hd).astype(q.dtype)


def ref_decode_attention(q, k, v, slot_pos, pos, *, scale=None, softcap=0.0,
                         window=0):
    """q: (B,H,hd); k,v: (B,K,S,hd); slot_pos (S,) or (B,S); pos scalar
    or (B,) — per-row positions for the continuous-batching decode path."""
    b, h, hd = q.shape
    kheads, s = k.shape[1], k.shape[2]
    group = h // kheads
    if scale is None:
        scale = hd ** -0.5
    qg = q.reshape(b, kheads, group, hd).astype(jnp.float32)
    logits = jnp.einsum("bkgh,bkth->bkgt", qg, k.astype(jnp.float32)) * scale
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    slot_pos = jnp.broadcast_to(jnp.asarray(slot_pos).reshape(-1, s), (b, s))
    pos = jnp.broadcast_to(jnp.asarray(pos).reshape(-1), (b,))
    valid = (slot_pos >= 0) & (slot_pos <= pos[:, None])
    if window:
        valid &= pos[:, None] - slot_pos < window
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgt,bkth->bkgh", p, v.astype(jnp.float32))
    return o.reshape(b, h, hd).astype(q.dtype)


def ref_vtrace_scan(deltas, dcs):
    """Reverse first-order recurrence via lax.scan (matches core.vtrace)."""
    def body(acc, xs):
        d, dc = xs
        acc = d + dc * acc
        return acc, acc

    _, acc = jax.lax.scan(body, jnp.zeros_like(deltas[0]),
                          (deltas.astype(jnp.float32),
                           dcs.astype(jnp.float32)), reverse=True)
    return acc


def ref_ssd_chunk(c, b, xdt, da, h_prev):
    """Oracle for kernels/ssd_chunk.py — mirrors models/mamba.py chunk_step
    for a single (batch*head) slice set. Shapes as in ssd_chunk."""
    c = c.astype(jnp.float32)
    b = b.astype(jnp.float32)
    x = xdt.astype(jnp.float32)
    da = da.astype(jnp.float32)[..., 0]          # (BH, L)
    h = h_prev.astype(jnp.float32)
    acs = jnp.cumsum(da, axis=-1)                # (BH, L)
    seg = acs[:, :, None] - acs[:, None, :]
    l = c.shape[1]
    mask = jnp.tril(jnp.ones((l, l), bool))
    lmat = jnp.where(mask, jnp.exp(seg), 0.0)
    scores = jnp.einsum("gln,gsn->gls", c, b) * lmat
    y = jnp.einsum("gls,gsp->glp", scores, x)
    y = y + jnp.einsum("gln,gpn->glp", c, h) * jnp.exp(acs)[..., None]
    w = jnp.exp(acs[:, -1:] - acs)               # (BH, L)
    h_new = h * jnp.exp(acs[:, -1])[:, None, None] + \
        jnp.einsum("glp,gln,gl->gpn", x, b, w)
    return y.astype(xdt.dtype), h_new
