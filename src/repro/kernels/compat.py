"""Pallas-TPU API compatibility across jax versions."""

from jax.experimental.pallas import tpu as pltpu

# Renamed TPUCompilerParams -> CompilerParams after jax 0.4.x.
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
