"""Pallas-TPU API compatibility across jax versions and backends."""

import warnings

import jax
from jax.experimental.pallas import tpu as pltpu

# Renamed TPUCompilerParams -> CompilerParams after jax 0.4.x.
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

_warned = False


def resolve_interpret(interpret=None):
    """Resolve a caller's ``interpret=`` request against the backend.

    ``None`` (the default everywhere) auto-selects: compiled on TPU,
    interpret mode elsewhere — the kernels target Mosaic-TPU, and
    interpret mode executes the same kernel body under the CPU/GPU
    backend so the ``kernel`` impls stay runnable (and parity-testable)
    in CI. The fallback warns ONCE per process; callers no longer plumb
    ``interpret=`` flags by hand.
    """
    global _warned
    if interpret is not None:
        return interpret
    if jax.default_backend() == "tpu":
        return False
    if not _warned:
        _warned = True
        warnings.warn(
            "Pallas kernels: no TPU backend detected "
            f"({jax.default_backend()}); running in interpret mode "
            "(slow, validation only).", stacklevel=2)
    return True
