"""Pallas-TPU API compatibility across jax versions and backends."""

import warnings

import jax
from jax.experimental.pallas import tpu as pltpu

# Renamed TPUCompilerParams -> CompilerParams after jax 0.4.x.
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

_stats = {"explicit": 0, "compiled": 0, "fallbacks": 0}


def resolve_interpret(interpret=None):
    """Resolve a caller's ``interpret=`` request against the backend.

    ``None`` (the default everywhere) auto-selects: compiled on TPU,
    interpret mode elsewhere — the kernels target Mosaic-TPU, and
    interpret mode executes the same kernel body under the CPU/GPU
    backend so the ``kernel`` impls stay runnable (and parity-testable)
    in CI. The fallback warns once per process, and every resolution is
    counted: ``resolve_interpret.stats()`` lets tests and the static
    auditor assert that no path which requested ``impl=kernel`` fell
    back to interpret mode *silently*.
    """
    if interpret is not None:
        _stats["explicit"] += 1
        return interpret
    if jax.default_backend() == "tpu":
        _stats["compiled"] += 1
        return False
    if _stats["fallbacks"] == 0:
        warnings.warn(
            "Pallas kernels: no TPU backend detected "
            f"({jax.default_backend()}); running in interpret mode "
            "(slow, validation only).", stacklevel=2)
    _stats["fallbacks"] += 1
    return True


def _stats_snapshot():
    return dict(_stats)


def _stats_reset():
    for k in _stats:
        _stats[k] = 0


resolve_interpret.stats = _stats_snapshot
resolve_interpret.reset_stats = _stats_reset
