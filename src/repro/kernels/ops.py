"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (validation mode) and False on TPU —
the kernels are written for the TPU target; interpret mode executes the
kernel body for correctness checking in this container (DESIGN.md §8.5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import ssd_chunk as _ssd
from repro.kernels import vtrace as _vt


def _default_interpret():
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, scale=None, causal=True, window=0,
                    softcap=0.0, block_q=128, block_k=128, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _fa.flash_attention(q, k, v, scale=scale, causal=causal,
                               window=window, softcap=softcap,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


def decode_attention(q, k, v, slot_pos, pos, *, scale=None, softcap=0.0,
                     window=0, block_k=128, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _dec.decode_attention(q, k, v, slot_pos, pos, scale=scale,
                                 softcap=softcap, window=window,
                                 block_k=block_k, interpret=interpret)


def vtrace_acc(deltas, dcs, *, block_b=128, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _vt.vtrace_scan(deltas, dcs, block_b=block_b,
                           interpret=interpret)


def vtrace_from_importance_weights_kernel(
        log_rhos, discounts, rewards, values, bootstrap_value, *,
        clip_rho_threshold=1.0, clip_c_threshold=1.0,
        clip_pg_rho_threshold=1.0, interpret=None):
    """Full V-trace with the recursion on the Pallas kernel (drop-in for
    core.vtrace.vtrace_from_importance_weights)."""
    from repro.core.vtrace import VTraceReturns

    log_rhos = log_rhos.astype(jnp.float32)
    discounts = discounts.astype(jnp.float32)
    rewards = rewards.astype(jnp.float32)
    values = values.astype(jnp.float32)
    bootstrap_value = bootstrap_value.astype(jnp.float32)

    rhos = jnp.exp(log_rhos)
    clipped_rhos = jnp.minimum(clip_rho_threshold, rhos)
    cs = jnp.minimum(clip_c_threshold, rhos)
    values_tp1 = jnp.concatenate([values[1:], bootstrap_value[None]], 0)
    deltas = clipped_rhos * (rewards + discounts * values_tp1 - values)

    acc = vtrace_acc(deltas, discounts * cs, interpret=interpret)
    vs = values + acc
    vs_tp1 = jnp.concatenate([vs[1:], bootstrap_value[None]], 0)
    pg_rhos = jnp.minimum(clip_pg_rho_threshold, rhos)
    pg_adv = pg_rhos * (rewards + discounts * vs_tp1 - values)
    return VTraceReturns(jax.lax.stop_gradient(vs),
                         jax.lax.stop_gradient(pg_adv))


def ssd_chunk(c, b, xdt, da, h_prev, *, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _ssd.ssd_chunk(c, b, xdt, da, h_prev, interpret=interpret)
