"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to compiled on TPU and interpret mode elsewhere
(one process-wide warning) via :func:`repro.kernels.compat.resolve_interpret`
— the kernels are written for the TPU target; interpret mode executes the
kernel body for correctness checking in this container (DESIGN.md §8.5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# The shared fp32 mask constant for every masked-attention path — the model
# (models/attention.py) and the flash/decode kernels must agree on it or
# XLA-vs-kernel parity drifts on fully-masked rows. It MUST be defined
# before the kernel submodule imports below: the submodules import it back
# from this (then partially-initialised) module.
NEG_INF = -2.0e38

from repro.kernels import decode_attention as _dec  # noqa: E402
from repro.kernels import flash_attention as _fa  # noqa: E402
from repro.kernels import ref as _ref  # noqa: E402
from repro.kernels import ssd_chunk as _ssd  # noqa: E402
from repro.kernels import vtrace as _vt  # noqa: E402
from repro.kernels.compat import resolve_interpret  # noqa: E402


def flash_attention(q, k, v, *, scale=None, causal=True, window=0,
                    softcap=0.0, block_q=128, block_k=128, interpret=None):
    return _fa.flash_attention(q, k, v, scale=scale, causal=causal,
                               window=window, softcap=softcap,
                               block_q=block_q, block_k=block_k,
                               interpret=resolve_interpret(interpret))


def decode_attention(q, k, v, slot_pos, pos, *, scale=None, softcap=0.0,
                     window=0, block_k=128, interpret=None):
    return _dec.decode_attention(q, k, v, slot_pos, pos, scale=scale,
                                 softcap=softcap, window=window,
                                 block_k=block_k,
                                 interpret=resolve_interpret(interpret))


def vtrace_acc(deltas, dcs, *, block_b=128, interpret=None):
    return _vt.vtrace_scan(deltas, dcs, block_b=block_b,
                           interpret=resolve_interpret(interpret))


def vtrace_from_importance_weights_kernel(
        log_rhos, discounts, rewards, values, bootstrap_value, *,
        clip_rho_threshold=1.0, clip_c_threshold=1.0,
        clip_pg_rho_threshold=1.0, interpret=None):
    """Full V-trace with the recursion on the Pallas kernel (drop-in for
    core.vtrace.vtrace_from_importance_weights)."""
    from repro.core.vtrace import VTraceReturns

    log_rhos = log_rhos.astype(jnp.float32)
    discounts = discounts.astype(jnp.float32)
    rewards = rewards.astype(jnp.float32)
    values = values.astype(jnp.float32)
    bootstrap_value = bootstrap_value.astype(jnp.float32)

    rhos = jnp.exp(log_rhos)
    clipped_rhos = jnp.minimum(clip_rho_threshold, rhos)
    cs = jnp.minimum(clip_c_threshold, rhos)
    values_tp1 = jnp.concatenate([values[1:], bootstrap_value[None]], 0)
    deltas = clipped_rhos * (rewards + discounts * values_tp1 - values)

    acc = vtrace_acc(deltas, discounts * cs, interpret=interpret)
    vs = values + acc
    vs_tp1 = jnp.concatenate([vs[1:], bootstrap_value[None]], 0)
    pg_rhos = jnp.minimum(clip_pg_rho_threshold, rhos)
    pg_adv = pg_rhos * (rewards + discounts * vs_tp1 - values)
    return VTraceReturns(jax.lax.stop_gradient(vs),
                         jax.lax.stop_gradient(pg_adv))


def ssd_chunk(c, b, xdt, da, h_prev, *, interpret=None):
    return _ssd.ssd_chunk(c, b, xdt, da, h_prev,
                          interpret=resolve_interpret(interpret))


def ssd_chunk_trainable(c, b, xdt, da, h_prev, *, interpret=None):
    """``ssd_chunk`` with a custom VJP: Pallas kernel on the forward, VJP
    of the jnp reference on the backward (Pallas TPU kernels are not
    reverse-mode differentiable; the reference recomputes the chunk —
    flash-style rematerialisation)."""

    @jax.custom_vjp
    def run(c, b, xdt, da, h_prev):
        return ssd_chunk(c, b, xdt, da, h_prev, interpret=interpret)

    def fwd(c, b, xdt, da, h_prev):
        return run(c, b, xdt, da, h_prev), (c, b, xdt, da, h_prev)

    def bwd(res, g):
        return jax.vjp(_ref.ref_ssd_chunk, *res)[1](g)

    run.defvjp(fwd, bwd)
    return run(c, b, xdt, da, h_prev)
