"""Pallas TPU flash attention (prefill/train path).

Online-softmax attention with causal masking, optional sliding window,
optional logit softcap, and GQA (q heads grouped onto kv heads via the
BlockSpec index maps — no KV replication in HBM).

Grid: (batch * q_heads, num_q_blocks, num_kv_blocks), kv innermost so the
(m, l, acc) running state lives in VMEM scratch across kv iterations.
Fully-masked kv blocks (above the causal diagonal / outside the window) are
skipped with pl.when — the TPU-native equivalent of the CUDA early-exit.

Block sizes default to (128, 128): MXU-aligned (128x128 systolic array),
and the working set  bq*hd + 2*bk*hd + bq*bk  floats stays well under the
~16 MB v5e VMEM budget for hd <= 256.

Layout: q (B, H, S, hd); k, v (B, K, S, hd); out (B, H, S, hd).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.ops import NEG_INF


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, softcap, bq, bk, num_kv_blocks):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk

    # visit only blocks that can contain unmasked entries
    live = True
    if causal:
        live = k_start <= q_start + bq - 1
    if window:
        live = jnp.logical_and(live, q_start - (k_start + bk - 1) < window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                     # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                     # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "block_q",
                     "block_k", "interpret"))
def flash_attention(q, k, v, *, scale=None, causal=True, window=0,
                    softcap=0.0, block_q=128, block_k=128, interpret=False):
    """q: (B,H,S,hd); k,v: (B,K,S,hd) with H % K == 0. Returns (B,H,S,hd)."""
    b, h, s, hd = q.shape
    kheads = k.shape[1]
    assert h % kheads == 0, (h, kheads)
    group = h // kheads
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    nq, nk = s // bq, s // bk
    if scale is None:
        scale = hd ** -0.5

    qf = q.reshape(b * h, s, hd)
    kf = k.reshape(b * kheads, s, hd)
    vf = v.reshape(b * kheads, s, hd)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        # bh indexes (b, h); the kv row is (b, h // group)
        return ((bh // h) * kheads + (bh % h) // group, ki, 0)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        bq=bq, bk=bk, num_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), q_map),
            pl.BlockSpec((1, bk, hd), kv_map),
            pl.BlockSpec((1, bk, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # m
            pltpu.VMEM((bq,), jnp.float32),       # l
            pltpu.VMEM((bq, hd), jnp.float32),    # acc
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, hd)
