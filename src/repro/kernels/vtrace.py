"""Pallas TPU V-trace kernel — the paper's core algorithmic compute.

The V-trace backward recursion  acc_t = delta_t + (gamma_t c_t) acc_{t+1}
is a first-order linear recurrence over time. TPU adaptation: block the
batch dimension into 128-wide lanes (grid) and run the time recursion as an
on-chip fori_loop over sublane rows held entirely in VMEM — the whole
(T, 128) tile is resident, so the sequential dependency costs no HBM
traffic (memory-bound op: one read of deltas/dcs, one write of acc).

Inputs are precomputed by the ops.py wrapper from (log_rhos, discounts,
rewards, values, bootstrap): deltas (T, B) and dcs = discounts * cs (T, B).
Output: acc (T, B) with vs = values + acc.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams


def _kernel(deltas_ref, dcs_ref, acc_ref, *, t_len):
    def body(i, carry):
        t = t_len - 1 - i
        acc = deltas_ref[t, :] + dcs_ref[t, :] * carry
        acc_ref[t, :] = acc
        return acc

    zero = jnp.zeros_like(deltas_ref[0, :])
    jax.lax.fori_loop(0, t_len, body, zero)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def vtrace_scan(deltas, dcs, *, block_b=128, interpret=False):
    """deltas, dcs: (T, B) float32 -> acc (T, B) float32."""
    t, b = deltas.shape
    bb = min(block_b, b)
    assert b % bb == 0, (b, bb)

    kernel = functools.partial(_kernel, t_len=t)
    return pl.pallas_call(
        kernel,
        grid=(b // bb,),
        in_specs=[
            pl.BlockSpec((t, bb), lambda bi: (0, bi)),
            pl.BlockSpec((t, bb), lambda bi: (0, bi)),
        ],
        out_specs=pl.BlockSpec((t, bb), lambda bi: (0, bi)),
        out_shape=jax.ShapeDtypeStruct((t, b), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(deltas.astype(jnp.float32), dcs.astype(jnp.float32))
