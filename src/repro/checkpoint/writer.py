"""Background checkpoint writer: snapshot-then-write off the hot path.

The Runtime takes a synchronous ``checkpoint.snapshot`` (host copies of
this process's shards — the only part that must see a consistent device
state) and hands it here; the single writer thread does the disk I/O and
the multi-host completion barrier, so the learner never blocks on disk.

One thread, one FIFO queue: writes land in submission order, so a later
step can never become the "latest" checkpoint before an earlier one.
``flush()`` blocks until the queue drains and re-raises the first
background failure; ``close()`` additionally joins the thread — the
Runtime calls it on every exit path, so no writer thread outlives its
run (concurrency_lint: thread-no-join clean).
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Optional

from repro.checkpoint import checkpoint as _ckpt


class CheckpointWriteError(RuntimeError):
    """A background checkpoint write failed; raised at the next
    ``flush()``/``close()`` so the failure surfaces on the main thread."""


class AsyncCheckpointWriter:
    def __init__(self, print_fn: Callable[[str], None] = print):
        self._print_fn = print_fn
        self._q: queue.Queue = queue.Queue()
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[tuple] = None          # (path, exception)

    def submit(self, path: str, snap, metadata: Optional[dict] = None,
               ) -> None:
        """Queue one snapshot for persistence; returns immediately."""
        with self._lock:
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._run, name="ckpt-writer", daemon=True)
                self._thread.start()
        self._q.put((path, snap, metadata))

    def _run(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                path, snap, metadata = item
                try:
                    _ckpt.write_snapshot(path, snap, metadata)
                    self._print_fn(f"saved {path}")
                except Exception as exc:
                    with self._lock:
                        if self._error is None:
                            self._error = (path, exc)
                    self._print_fn(
                        f"checkpoint write failed for {path}: {exc!r}")
            finally:
                self._q.task_done()

    def _raise_pending(self) -> None:
        with self._lock:
            err = self._error
        if err is not None:
            path, exc = err
            raise CheckpointWriteError(
                f"background checkpoint write failed for {path}: "
                f"{exc!r}") from exc

    def flush(self) -> None:
        """Block until every submitted write has landed (manifest barrier
        included); re-raise the first background failure."""
        self._q.join()
        self._raise_pending()

    def close(self, raise_on_error: bool = True) -> None:
        """Drain the queue and join the writer thread. With
        ``raise_on_error=False`` (the Runtime's ``finally`` path) a
        pending failure is left to the log line it already printed
        instead of masking the in-flight exception."""
        with self._lock:
            thread = self._thread
            self._thread = None
        if thread is not None:
            self._q.put(None)
            thread.join()
        if raise_on_error:
            self._raise_pending()
