from repro.checkpoint.checkpoint import (latest_step_path, restore,  # noqa: F401
                                         restore_structured, save)
