from repro.checkpoint.checkpoint import latest_step_path, restore, save  # noqa: F401
