from repro.checkpoint.checkpoint import (MANIFEST, is_complete,  # noqa: F401
                                         latest_step_path, load_flat,
                                         read_metadata, restore,
                                         restore_structured, save,
                                         saved_shardings, snapshot,
                                         write_snapshot)
from repro.checkpoint.writer import (AsyncCheckpointWriter,  # noqa: F401
                                     CheckpointWriteError)
