"""Sharded manifest checkpoints (no orbax in the container).

A checkpoint is a DIRECTORY, written cooperatively by every process of a
(possibly multi-host) run:

    step_40/
      shard-00000.npz    per-process arrays: the addressable replica-0
      shard-00001.npz    shards of every learner-tree leaf, plus that
      ...                process's structured (source) state
      shard-00000.json   per-process sidecar: shard index/shape/dtype per
      ...                leaf, structured schema, metadata
      manifest.json      merged by process 0 AFTER a cross-process
                         barrier — the COMPLETION MARKER

Each process writes only the shards its devices actually hold
(``addressable_shards`` with ``replica_id == 0``, so every unique piece of
data is written exactly once across the fleet), records their global index
in its sidecar, and process 0 merges the sidecars into ``manifest.json``
once every process has landed its files. All file writes are
write-to-temp + ``os.replace``, and readers treat a step directory
without ``manifest.json`` as nonexistent — a SIGKILL at ANY point during
a save leaves the previous checkpoint as the latest restorable one.

``restore(..., shardings=)`` reassembles leaves straight onto a mesh via
``jax.make_array_from_single_device_arrays`` — and the target mesh does
NOT have to match the saved one (**elastic resume**): each target shard
is assembled from whichever saved shards overlap its index, so a run
checkpointed on ``("data","model") = (2, 2)`` restores onto ``(4, 1)``
(or onto a different process count) by resharding from the manifest.

Two content layers, as before:

* ``save``/``restore`` — fixed-structure trees (params/opt_state),
  restored into a ``like`` template. This is the learner-state path.
* ``structured=``/``restore_structured`` — SELF-DESCRIBING, per-process
  trees whose shape is only known at save time (RolloutSource
  ``state_dict()``s: env carries, RNG streams, replay slots — any nesting
  of dict/list/tuple/None/scalar/array). Each process saves and restores
  its own; on a process-count change the learner state still restores
  (it reshards) but source state comes back ``None`` and the source
  starts fresh.

The snapshot/write split (``snapshot()`` -> ``write_snapshot()``) is what
moves checkpointing off the hot path: ``snapshot`` synchronously copies
every addressable shard to host memory (the only part that must see a
consistent device state), and ``write_snapshot`` — all the disk I/O and
the cross-process barrier — runs wherever the caller likes, e.g. the
background thread of ``checkpoint.writer.AsyncCheckpointWriter``.

Legacy single-file ``step_N.npz`` checkpoints (pre-manifest) still
restore through every read API.
"""

from __future__ import annotations

import itertools
import json
import os
import tempfile
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

MANIFEST = "manifest.json"
_FORMAT = 2
_SCHEMA_KEY = "__structured_schema__"      # legacy npz layout
_STRUCT_PREFIX = "__structured__/"

# Cross-process barrier timeout: a process that dies mid-save leaves its
# peers with a clean error here instead of a silent hang.
_BARRIER_TIMEOUT_MS = 180_000
_barrier_seq = itertools.count()


def _key_str(k):
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"#{k.idx}"
    return str(k)


def _shard_npz(pid: int) -> str:
    return f"shard-{pid:05d}.npz"


def _shard_json(pid: int) -> str:
    return f"shard-{pid:05d}.json"


def _resolve_index(index, shape) -> List[List[int]]:
    """Concrete [[start, stop], ...] for a shard's global index (a tuple
    of slices, possibly with None bounds / missing trailing dims)."""
    out = []
    for d, dim in enumerate(shape):
        s = index[d] if d < len(index) else slice(None)
        if s.step not in (None, 1):
            raise ValueError(f"non-unit-stride shard index {index!r}")
        out.append([0 if s.start is None else int(s.start),
                    dim if s.stop is None else int(s.stop)])
    return out


def _full_index(shape) -> List[List[int]]:
    return [[0, d] for d in shape]


def _as_slices(index: Sequence[Sequence[int]]) -> Tuple[slice, ...]:
    return tuple(slice(a, b) for a, b in index)


def _atomic_write(path: str, write_fn) -> None:
    """Write via ``write_fn(file_object)`` to a temp file in the target
    directory, then ``os.replace`` — readers never observe a torn file."""
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            write_fn(f)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def _barrier(name: str, process_count: int) -> None:
    """Host-side cross-process rendezvous through the jax.distributed
    coordination service (no device computation — safe from a background
    writer thread). No-op single-process."""
    if process_count <= 1:
        return
    from jax._src import distributed
    client = distributed.global_state.client
    if client is None:
        raise RuntimeError(
            "multi-process checkpoint save needs the jax.distributed "
            "bootstrap (launch/multihost.py) for its completion barrier")
    client.wait_at_barrier(name, _BARRIER_TIMEOUT_MS)


# ---------------------------------------------------------------------------
# structured (self-describing) encode/decode — shared by both formats
# ---------------------------------------------------------------------------


def _encode(obj, flat: Dict[str, Any], path: str) -> dict:
    """Encode an arbitrary pytree into (flat arrays, JSON schema). Scalars
    live in the schema; array leaves are COPIED into ``flat`` under
    ``path`` (a copy, so a snapshot stays frozen while the source keeps
    mutating its live buffers). NamedTuples degrade to plain tuples —
    restore against a live template (tree_unflatten) when the node type
    matters."""
    if obj is None:
        return {"t": "none"}
    if isinstance(obj, (bool, int, float, str)):
        return {"t": "py", "v": obj}
    if isinstance(obj, (np.integer, np.floating, np.bool_)):
        return {"t": "py", "v": obj.item()}
    if isinstance(obj, dict):
        return {"t": "dict", "items": {
            str(k): _encode(v, flat, f"{path}/{k}") for k, v in obj.items()}}
    if isinstance(obj, (list, tuple)):
        return {"t": "tuple" if isinstance(obj, tuple) else "list",
                "items": [_encode(v, flat, f"{path}/{i}")
                          for i, v in enumerate(obj)]}
    if isinstance(obj, jax.Array) and not obj.is_fully_addressable:
        raise TypeError(
            f"structured state at {path!r} holds a non-fully-addressable "
            "array — source state must be host-local (per-process)")
    arr = np.array(obj)                      # always copy
    if arr.dtype == object:
        raise TypeError(f"cannot checkpoint object-dtype leaf at {path!r}")
    flat[path] = arr
    return {"t": "arr", "k": path}


def _decode(node: dict, data) -> Any:
    t = node["t"]
    if t == "none":
        return None
    if t == "py":
        return node["v"]
    if t == "dict":
        return {k: _decode(v, data) for k, v in node["items"].items()}
    if t == "list":
        return [_decode(v, data) for v in node["items"]]
    if t == "tuple":
        return tuple(_decode(v, data) for v in node["items"])
    if t == "arr":
        return np.asarray(data[node["k"]])
    raise ValueError(f"unknown schema node type {t!r}")


# ---------------------------------------------------------------------------
# snapshot: synchronous device -> host copy of this process's shards
# ---------------------------------------------------------------------------


@dataclass
class _LeafSnap:
    shape: List[int]
    dtype: str
    spec: Optional[list]                      # saved PartitionSpec, if any
    shards: List[Tuple[List[List[int]], np.ndarray]]  # (index, host copy)


@dataclass
class Snapshot:
    """Host-side, immutable copy of everything this process contributes to
    one checkpoint — safe to hand to a background writer while training
    mutates the live arrays."""
    leaves: Dict[str, _LeafSnap]
    structured: Dict[str, dict] = field(default_factory=dict)   # schemas
    arrays: Dict[str, np.ndarray] = field(default_factory=dict)  # npz extras
    mesh: Optional[Dict[str, int]] = None


def snapshot(tree, structured: Optional[Dict[str, Any]] = None) -> Snapshot:
    """Copy this process's addressable replica-0 shards of every leaf (and
    any ``structured`` trees) to host memory. This is the only part of a
    save that must run synchronously with training; hand the result to
    ``write_snapshot`` (or an ``AsyncCheckpointWriter``) for the disk
    I/O. Works for single-device, sharded, and multi-host arrays alike —
    no fully-addressable requirement."""
    pid = jax.process_index()
    leaves: Dict[str, _LeafSnap] = {}
    mesh_desc = None
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_key_str(k) for k in path)
        if isinstance(leaf, jax.Array):
            spec = None
            sh = leaf.sharding
            if isinstance(sh, jax.sharding.NamedSharding):
                # JSON-portable PartitionSpec: each dim is null | axis name
                # | list of axis names — decoded by saved_shardings()
                spec = [list(p) if isinstance(p, tuple) else p
                        for p in sh.spec]
                if mesh_desc is None:
                    mesh_desc = {str(a): int(s)
                                 for a, s in sh.mesh.shape.items()}
            shards = [(_resolve_index(s.index, leaf.shape),
                       np.array(s.data))
                      for s in leaf.addressable_shards if s.replica_id == 0]
            leaves[key] = _LeafSnap(list(leaf.shape), str(leaf.dtype),
                                    spec, shards)
        else:
            arr = np.array(leaf)
            # plain host leaves are identical across an SPMD fleet: one
            # writer (process 0) is enough
            shards = [(_full_index(arr.shape), arr)] if pid == 0 else []
            leaves[key] = _LeafSnap(list(arr.shape), str(arr.dtype),
                                    None, shards)
    snap = Snapshot(leaves=leaves, mesh=mesh_desc)
    for name, obj in (structured or {}).items():
        if obj is None:
            continue
        snap.structured[name] = _encode(obj, snap.arrays,
                                        _STRUCT_PREFIX + name)
    return snap


# ---------------------------------------------------------------------------
# write: per-process shard files, barrier, process-0 manifest merge
# ---------------------------------------------------------------------------


def write_snapshot(path: str, snap: Snapshot,
                   metadata: Optional[dict] = None) -> None:
    """Persist one process's ``Snapshot`` under checkpoint directory
    ``path`` and (on process 0, after the all-processes barrier) write the
    merged ``manifest.json`` completion marker. Every process of the run
    must call this with the same ``path``."""
    pid = jax.process_index()
    nproc = jax.process_count()
    os.makedirs(path, exist_ok=True)

    arrays = dict(snap.arrays)
    tree_entries: Dict[str, dict] = {}
    for key, leaf in snap.leaves.items():
        shards = []
        for i, (index, arr) in enumerate(leaf.shards):
            npz_key = f"{key}@{i}"
            arrays[npz_key] = arr
            shards.append({"key": npz_key, "index": index})
        tree_entries[key] = {"shape": leaf.shape, "dtype": leaf.dtype,
                             "spec": leaf.spec, "shards": shards}

    _atomic_write(os.path.join(path, _shard_npz(pid)),
                  lambda f: np.savez(f, **arrays))
    sidecar = {"process": pid, "tree": tree_entries,
               "structured": snap.structured, "mesh": snap.mesh,
               "metadata": metadata or {}}
    _atomic_write(os.path.join(path, _shard_json(pid)),
                  lambda f: f.write(json.dumps(sidecar).encode()))

    # every process's shard files are on disk before the manifest (the
    # completion marker) can name them
    seq = next(_barrier_seq)
    _barrier(f"ckpt:{os.path.basename(path)}:{seq}:shards", nproc)
    if pid == 0:
        _atomic_write(os.path.join(path, MANIFEST),
                      lambda f: f.write(json.dumps(
                          _merge_manifest(path, nproc)).encode()))
    _barrier(f"ckpt:{os.path.basename(path)}:{seq}:done", nproc)


def _merge_manifest(path: str, nproc: int) -> dict:
    tree: Dict[str, dict] = {}
    structured: Dict[str, dict] = {}
    metadata: dict = {}
    mesh = None
    for pid in range(nproc):
        with open(os.path.join(path, _shard_json(pid)),
                  encoding="utf-8") as f:
            sc = json.load(f)
        fname = _shard_npz(pid)
        for key, entry in sc["tree"].items():
            tgt = tree.setdefault(key, {"shape": entry["shape"],
                                        "dtype": entry["dtype"],
                                        "spec": entry["spec"],
                                        "shards": []})
            tgt["shards"].extend(dict(s, file=fname)
                                 for s in entry["shards"])
        for name, schema in sc["structured"].items():
            structured.setdefault(name, {})[str(pid)] = {
                "file": fname, "schema": schema}
        if pid == 0:
            metadata = sc["metadata"]
            mesh = sc.get("mesh")
    return {"format": _FORMAT, "num_processes": nproc,
            "metadata": metadata, "mesh": mesh,
            "tree": tree, "structured": structured}


def save(path: str, tree, metadata: dict | None = None,
         structured: Dict[str, Any] | None = None) -> None:
    """Synchronous save: ``snapshot`` + ``write_snapshot``. ``structured``:
    optional name -> self-describing pytree (see module docstring); read
    back with ``restore_structured(path, name)``."""
    write_snapshot(path, snapshot(tree, structured), metadata)


# ---------------------------------------------------------------------------
# read side
# ---------------------------------------------------------------------------


def is_complete(path: str) -> bool:
    """True iff ``path`` is a restorable checkpoint: a manifest directory
    whose completion marker landed, or a legacy single-file .npz."""
    if os.path.isdir(path):
        return os.path.exists(os.path.join(path, MANIFEST))
    return os.path.isfile(path) and path.endswith(".npz")


def _read_manifest(path: str) -> dict:
    mpath = os.path.join(path, MANIFEST)
    if not os.path.exists(mpath):
        raise FileNotFoundError(
            f"{path} has no {MANIFEST} — the save never completed "
            "(killed mid-write); restore from an earlier step")
    with open(mpath, encoding="utf-8") as f:
        return json.load(f)


def read_metadata(path: str) -> dict:
    """The ``metadata`` dict a checkpoint was saved with (``step``, and —
    for Runtime checkpoints — the run config keys ``--resume`` validates
    before attempting a restore)."""
    if os.path.isdir(path):
        return _read_manifest(path).get("metadata", {})
    with np.load(path, allow_pickle=False) as data:
        return json.loads(str(data["__metadata__"]))


class _ShardFiles:
    """Lazily-opened npz handles for a checkpoint directory."""

    def __init__(self, path: str):
        self.path = path
        self._open: Dict[str, Any] = {}

    def __getitem__(self, fname: str):
        if fname not in self._open:
            self._open[fname] = np.load(os.path.join(self.path, fname),
                                        allow_pickle=False)
        return self._open[fname]

    def close(self):
        for f in self._open.values():
            f.close()
        self._open.clear()


def _assemble(key: str, entry: dict, files: _ShardFiles,
              target: Sequence[Sequence[int]]) -> np.ndarray:
    """Assemble the ``target`` index block of a leaf from whichever saved
    shards overlap it — the elastic-resume core: the target block may cut
    across saved shard boundaries arbitrarily."""
    tgt_shape = tuple(b - a for a, b in target)
    out = np.empty(tgt_shape, dtype=np.dtype(entry["dtype"]))
    covered = 0
    for shard in entry["shards"]:
        src_idx = shard["index"]
        dst, src = [], []
        vol = 1
        for (t0, t1), (s0, s1) in zip(target, src_idx):
            lo, hi = max(t0, s0), min(t1, s1)
            if lo >= hi:
                vol = 0
                break
            dst.append(slice(lo - t0, hi - t0))
            src.append(slice(lo - s0, hi - s0))
            vol *= hi - lo
        if vol == 0:
            continue
        data = files[shard["file"]][shard["key"]]
        out[tuple(dst)] = data[tuple(src)]    # 0-d: out[()] = data[()]
        covered += vol
    want = int(np.prod(tgt_shape, dtype=np.int64)) if tgt_shape else 1
    if covered != want:
        raise ValueError(
            f"checkpoint shards for {key!r} cover {covered}/{want} "
            f"elements of index {list(target)} — shard files are missing "
            "or the save was interrupted")
    return out


def _validate_tree(manifest: dict, template_keys: Sequence[str],
                   path: str) -> None:
    """Fail up front, naming mismatched keys, when the checkpoint was
    written by a different arch/config than the current run — instead of
    an opaque error deep inside pytree unflattening."""
    saved = set(manifest["tree"])
    want = set(template_keys)
    missing = sorted(want - saved)
    extra = sorted(saved - want)
    if missing or extra:
        def clip(keys):
            return ", ".join(keys[:6]) + (" …" if len(keys) > 6 else "")
        parts = [f"checkpoint {path} does not match this run's model/"
                 "config (wrong --arch / --mode / optimizer?):"]
        if missing:
            parts.append(f" this run expects keys the checkpoint lacks: "
                         f"[{clip(missing)}]")
        if extra:
            parts.append(f" the checkpoint has keys this run lacks: "
                         f"[{clip(extra)}]")
        raise ValueError("".join(parts))


def _restore_leaf(key: str, entry: dict, files: _ShardFiles, sharding):
    shape = tuple(entry["shape"])
    if sharding is None:
        return _assemble(key, entry, files, _full_index(shape))
    imap = sharding.addressable_devices_indices_map(shape)
    blocks: Dict[tuple, np.ndarray] = {}
    arrays = []
    for dev, idx in imap.items():
        target = _resolve_index(idx, shape)
        bkey = tuple(tuple(t) for t in target)
        if bkey not in blocks:
            blocks[bkey] = _assemble(key, entry, files, target)
        arrays.append(jax.device_put(blocks[bkey], dev))
    return jax.make_array_from_single_device_arrays(shape, sharding, arrays)


def restore(path: str, like, shardings=None):
    """Restore into the structure of ``like`` (values replaced).

    ``shardings``: optional tree of ``jax.sharding.Sharding`` matching
    ``like`` — each leaf is reassembled from its saved shards DIRECTLY
    onto that sharding via ``make_array_from_single_device_arrays``
    (model-sharded params land distributed; no host-replicated tree).
    The target shardings may come from a different mesh shape or process
    count than the save (elastic resume); without ``shardings`` each leaf
    comes back as a fully-assembled numpy array."""
    if not os.path.isdir(path):
        return _restore_legacy(path, like, shardings)
    manifest = _read_manifest(path)
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    keys = ["/".join(_key_str(k) for k in pk) for pk, _ in paths_leaves]
    _validate_tree(manifest, keys, path)
    if shardings is None:
        shard_leaves = [None] * len(paths_leaves)
    else:
        shard_leaves = jax.tree_util.tree_leaves(shardings)
        if len(shard_leaves) != len(paths_leaves):
            raise ValueError(
                f"shardings tree has {len(shard_leaves)} leaves but "
                f"the template has {len(paths_leaves)}")
    files = _ShardFiles(path)
    try:
        leaves = []
        for key, (_, leaf), sharding in zip(keys, paths_leaves,
                                            shard_leaves):
            entry = manifest["tree"][key]
            if hasattr(leaf, "dtype") \
                    and tuple(entry["shape"]) != tuple(leaf.shape):
                raise ValueError(
                    f"shape mismatch for {key}: {tuple(entry['shape'])} "
                    f"vs {tuple(leaf.shape)}")
            leaves.append(_restore_leaf(key, entry, files, sharding))
    finally:
        files.close()
    meta = manifest.get("metadata", {})
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


def saved_shardings(path: str, like_shardings):
    """Rebuild each leaf's SAVED partition spec as a NamedSharding on the
    LIVE mesh (taken from ``like_shardings``), for bit-exact same-mesh
    resume: by checkpoint time GSPMD may have normalised the run's specs
    away from the init-time ones (e.g. dropping a size-1 mesh axis), and
    restoring onto the exact saved specs makes the resumed step's compiled
    program — and therefore its bits — identical to the uninterrupted
    run's. Returns ``None`` when the checkpoint is legacy or was saved on
    a different mesh shape (elastic resume: the caller keeps its own
    shardings and the leaves reshard). Leaves the checkpoint recorded no
    spec for keep their ``like_shardings`` entry."""
    if not os.path.isdir(path):
        return None
    manifest = _read_manifest(path)
    leaves, treedef = jax.tree_util.tree_flatten(like_shardings)
    mesh = next((s.mesh for s in leaves
                 if isinstance(s, jax.sharding.NamedSharding)), None)
    if mesh is None:
        return None
    desc = {str(a): int(s) for a, s in mesh.shape.items()}
    if manifest.get("mesh") != desc:
        return None
    out = []
    for pk, fallback in jax.tree_util.tree_flatten_with_path(
            like_shardings)[0]:
        key = "/".join(_key_str(k) for k in pk)
        entry = manifest["tree"].get(key) or {}
        spec = entry.get("spec")
        out.append(fallback if spec is None else jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(
                *[tuple(p) if isinstance(p, list) else p for p in spec])))
    return jax.tree_util.tree_unflatten(treedef, out)


def load_flat(path: str):
    """(flat key -> fully-assembled numpy array, metadata) for every
    learner-tree leaf — format-agnostic; the test/debug view of a
    checkpoint's contents."""
    if not os.path.isdir(path):
        with np.load(path, allow_pickle=False) as data:
            meta = json.loads(str(data["__metadata__"]))
            flat = {k: np.asarray(data[k]) for k in data.files
                    if k != "__metadata__" and k != _SCHEMA_KEY
                    and not k.startswith(_STRUCT_PREFIX)}
            return flat, meta
    manifest = _read_manifest(path)
    files = _ShardFiles(path)
    try:
        flat = {key: _assemble(key, entry, files,
                               _full_index(tuple(entry["shape"])))
                for key, entry in manifest["tree"].items()}
    finally:
        files.close()
    return flat, manifest.get("metadata", {})


def restore_structured(path: str, name: str):
    """Restore THIS PROCESS's self-describing tree saved via
    ``save(..., structured={name: tree})``. ``None`` when the checkpoint
    predates it, the name is absent, or the checkpoint was written by a
    different process count (source state is per-process; the caller
    starts that piece fresh — the learner state still restores, elastically
    resharded)."""
    if not os.path.isdir(path):
        return _restore_structured_legacy(path, name)
    manifest = _read_manifest(path)
    entry = manifest.get("structured", {}).get(name)
    if entry is None:
        return None
    mine = entry.get(str(jax.process_index()))
    if mine is None:
        return None
    with np.load(os.path.join(path, mine["file"]),
                 allow_pickle=False) as data:
        return _decode(mine["schema"], data)


def latest_step_path(ckpt_dir: str):
    """The highest-step COMPLETE checkpoint under ``ckpt_dir`` — manifest
    directories without their completion marker (killed mid-write) are
    skipped, so a torn save can never shadow the last good step."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for f in os.listdir(ckpt_dir):
        name = f[:-4] if f.endswith(".npz") else f
        if not name.startswith("step_"):
            continue
        try:
            step = int(name[5:])
        except ValueError:
            continue
        full = os.path.join(ckpt_dir, f)
        if is_complete(full):
            steps.append((step, full))
    return max(steps)[1] if steps else None


# ---------------------------------------------------------------------------
# legacy single-file .npz checkpoints (pre-manifest) stay restorable
# ---------------------------------------------------------------------------


def _restore_legacy(path: str, like, shardings=None):
    with np.load(path, allow_pickle=False) as data:
        paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
        if shardings is None:
            shard_leaves = [None] * len(paths_leaves)
        else:
            shard_leaves = jax.tree_util.tree_leaves(shardings)
            if len(shard_leaves) != len(paths_leaves):
                raise ValueError(
                    f"shardings tree has {len(shard_leaves)} leaves but "
                    f"the template has {len(paths_leaves)}")
        leaves = []
        for (path_keys, leaf), sharding in zip(paths_leaves, shard_leaves):
            key = "/".join(_key_str(k) for k in path_keys)
            if key not in data:
                raise KeyError(f"checkpoint missing {key!r}")
            arr = data[key]
            if hasattr(leaf, "dtype") and arr.shape != leaf.shape:
                raise ValueError(
                    f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
            leaves.append(arr if sharding is None
                          else jax.device_put(arr, sharding))
        meta = json.loads(str(data["__metadata__"]))
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


def _restore_structured_legacy(path: str, name: str):
    with np.load(path, allow_pickle=False) as data:
        if _SCHEMA_KEY not in data:
            return None
        schemas = json.loads(str(data[_SCHEMA_KEY]))
        if name not in schemas:
            return None
        return _decode(schemas[name], data)
