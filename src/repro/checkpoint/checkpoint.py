"""Pytree checkpointing to .npz (no orbax in the container).

Flattens the (params, opt_state, step, ...) tree with '/'-joined key paths;
restores into the same structure. Atomic via write-to-temp + rename.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_key_str(k) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _key_str(k):
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"#{k.idx}"
    return str(k)


def save(path: str, tree, metadata: dict | None = None) -> None:
    flat = _flatten(tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __metadata__=json.dumps(metadata or {}), **flat)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def restore(path: str, like):
    """Restore into the structure of ``like`` (values replaced)."""
    with np.load(path, allow_pickle=False) as data:
        paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path_keys, leaf in paths_leaves:
            key = "/".join(_key_str(k) for k in path_keys)
            if key not in data:
                raise KeyError(f"checkpoint missing {key!r}")
            arr = data[key]
            if hasattr(leaf, "dtype") and arr.shape != leaf.shape:
                raise ValueError(
                    f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
            leaves.append(arr)
        meta = json.loads(str(data["__metadata__"]))
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


def latest_step_path(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for f in os.listdir(ckpt_dir):
        if f.startswith("step_") and f.endswith(".npz"):
            try:
                steps.append((int(f[5:-4]), os.path.join(ckpt_dir, f)))
            except ValueError:
                pass
    return max(steps)[1] if steps else None
