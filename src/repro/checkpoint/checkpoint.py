"""Pytree checkpointing to .npz (no orbax in the container).

Flattens the (params, opt_state, step, ...) tree with '/'-joined key paths;
restores into the same structure. Atomic via write-to-temp + rename.

Two layers:

* ``save``/``restore`` — fixed-structure trees (params/opt_state), restored
  into a ``like`` template. This is the learner-state path.
* ``structured=``/``restore_structured`` — SELF-DESCRIBING trees whose shape
  is only known at save time (RolloutSource ``state_dict()``s: env carries,
  RNG streams, replay-buffer slots, in-flight rollouts — any nesting of
  dict/list/tuple/None/scalar/array). The structure rides along as a JSON
  schema in the same .npz, so ``restore_structured`` needs no template and
  checkpoints written before a source grew state restore cleanly (returns
  ``None``).
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_key_str(k) for k in path)
        # np.asarray gathers model-sharded jax.Arrays to host — correct on
        # a single process; a multi-host global array has shards this
        # process cannot read, so fail loudly instead of saving garbage.
        if isinstance(leaf, jax.Array) and not leaf.is_fully_addressable:
            raise ValueError(
                f"cannot checkpoint non-fully-addressable array at {key!r} "
                "— multi-host save needs a cross-host gather (ROADMAP "
                "residue); checkpoint from a single-host run")
        flat[key] = np.asarray(leaf)
    return flat


def _key_str(k):
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"#{k.idx}"
    return str(k)


# -- self-describing trees (source state) -----------------------------------

_SCHEMA_KEY = "__structured_schema__"
_STRUCT_PREFIX = "__structured__/"


def _encode(obj, flat: Dict[str, Any], path: str) -> dict:
    """Encode an arbitrary pytree into (flat arrays, JSON schema). Scalars
    live in the schema; array leaves go to ``flat`` under ``path``.
    NamedTuples degrade to plain tuples — restore against a live template
    (tree_unflatten) when the node type matters."""
    if obj is None:
        return {"t": "none"}
    if isinstance(obj, (bool, int, float, str)):
        return {"t": "py", "v": obj}
    if isinstance(obj, (np.integer, np.floating, np.bool_)):
        return {"t": "py", "v": obj.item()}
    if isinstance(obj, dict):
        return {"t": "dict", "items": {
            str(k): _encode(v, flat, f"{path}/{k}") for k, v in obj.items()}}
    if isinstance(obj, (list, tuple)):
        return {"t": "tuple" if isinstance(obj, tuple) else "list",
                "items": [_encode(v, flat, f"{path}/{i}")
                          for i, v in enumerate(obj)]}
    arr = np.asarray(obj)
    if arr.dtype == object:
        raise TypeError(f"cannot checkpoint object-dtype leaf at {path!r}")
    flat[path] = arr
    return {"t": "arr", "k": path}


def _decode(node: dict, data) -> Any:
    t = node["t"]
    if t == "none":
        return None
    if t == "py":
        return node["v"]
    if t == "dict":
        return {k: _decode(v, data) for k, v in node["items"].items()}
    if t == "list":
        return [_decode(v, data) for v in node["items"]]
    if t == "tuple":
        return tuple(_decode(v, data) for v in node["items"])
    if t == "arr":
        return np.asarray(data[node["k"]])
    raise ValueError(f"unknown schema node type {t!r}")


def save(path: str, tree, metadata: dict | None = None,
         structured: Dict[str, Any] | None = None) -> None:
    """``structured``: optional name -> self-describing pytree (see module
    docstring); read back with ``restore_structured(path, name)``."""
    flat = _flatten(tree)
    if structured:
        schemas = {name: _encode(obj, flat, _STRUCT_PREFIX + name)
                   for name, obj in structured.items()}
        flat[_SCHEMA_KEY] = json.dumps(schemas)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __metadata__=json.dumps(metadata or {}), **flat)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def restore(path: str, like, shardings=None):
    """Restore into the structure of ``like`` (values replaced).

    ``shardings``: optional tree of ``jax.sharding.Sharding`` matching
    ``like`` — each leaf is ``device_put`` onto its sharding as it is
    read (the sharded-aware restore path: model-sharded params land
    directly on the mesh, instead of materialising a host-replicated
    numpy tree the caller then re-places)."""
    with np.load(path, allow_pickle=False) as data:
        paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
        if shardings is None:
            shard_leaves = [None] * len(paths_leaves)
        else:
            shard_leaves = jax.tree_util.tree_leaves(shardings)
            if len(shard_leaves) != len(paths_leaves):
                raise ValueError(
                    f"shardings tree has {len(shard_leaves)} leaves but "
                    f"the template has {len(paths_leaves)}")
        leaves = []
        for (path_keys, leaf), sharding in zip(paths_leaves, shard_leaves):
            key = "/".join(_key_str(k) for k in path_keys)
            if key not in data:
                raise KeyError(f"checkpoint missing {key!r}")
            arr = data[key]
            if hasattr(leaf, "dtype") and arr.shape != leaf.shape:
                raise ValueError(
                    f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
            leaves.append(arr if sharding is None
                          else jax.device_put(arr, sharding))
        meta = json.loads(str(data["__metadata__"]))
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


def restore_structured(path: str, name: str):
    """Restore a self-describing tree saved via ``save(..., structured=
    {name: tree})``; ``None`` when the checkpoint predates it (old
    checkpoints stay restorable — the caller starts that piece fresh)."""
    with np.load(path, allow_pickle=False) as data:
        if _SCHEMA_KEY not in data:
            return None
        schemas = json.loads(str(data[_SCHEMA_KEY]))
        if name not in schemas:
            return None
        return _decode(schemas[name], data)


def latest_step_path(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for f in os.listdir(ckpt_dir):
        if f.startswith("step_") and f.endswith(".npz"):
            try:
                steps.append((int(f[5:-4]), os.path.join(ckpt_dir, f)))
            except ValueError:
                pass
    return max(steps)[1] if steps else None
