"""MinAtar-style 10x10 grid collection game (the paper's canonical
adaptation target — Figs. 1-2 swap PolyBeast onto MinAtar).

The agent (5 actions: noop/up/down/left/right) collects food (+1) and must
avoid a hazard (-1, ends episode). Episode also ends after MAX_STEPS.
Observation: (10, 10, 4) float32 channels [agent, food, hazard, time-left].
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.envs.base import Env, auto_reset

SIZE = 10
NUM_ACTIONS = 5
NUM_FOOD = 3
MAX_STEPS = 100

# numpy on purpose: a module-level jnp.array would initialise the jax
# backend at import time, which forecloses jax.distributed.initialize()
# (the multi-host bootstrap must run before any jax computation).
_MOVES = np.array([[0, 0], [-1, 0], [1, 0], [0, -1], [0, 1]], np.int32)


class GridState(NamedTuple):
    agent: jnp.ndarray      # (2,) int32
    food: jnp.ndarray       # (NUM_FOOD, 2) int32
    food_alive: jnp.ndarray  # (NUM_FOOD,) bool
    hazard: jnp.ndarray     # (2,) int32
    t: jnp.ndarray          # () int32


def _obs(state):
    board = jnp.zeros((SIZE, SIZE, 4), jnp.float32)
    board = board.at[state.agent[0], state.agent[1], 0].set(1.0)
    for i in range(NUM_FOOD):
        board = board.at[state.food[i, 0], state.food[i, 1], 1].set(
            state.food_alive[i].astype(jnp.float32))
    board = board.at[state.hazard[0], state.hazard[1], 2].set(1.0)
    board = board.at[:, :, 3].set(1.0 - state.t / MAX_STEPS)
    return board


def _reset(key):
    ks = jax.random.split(key, 3)
    agent = jax.random.randint(ks[0], (2,), 0, SIZE)
    food = jax.random.randint(ks[1], (NUM_FOOD, 2), 0, SIZE)
    hazard = jax.random.randint(ks[2], (2,), 0, SIZE)
    state = GridState(agent, food, jnp.ones((NUM_FOOD,), bool), hazard,
                      jnp.zeros((), jnp.int32))
    return state, _obs(state)


def _step(state, action, key):
    agent = jnp.clip(state.agent + jnp.asarray(_MOVES)[action], 0, SIZE - 1)
    on_food = (state.food == agent[None]).all(-1) & state.food_alive
    reward = on_food.sum().astype(jnp.float32)
    food_alive = state.food_alive & ~on_food
    # collected food respawns
    new_food = jax.random.randint(key, (NUM_FOOD, 2), 0, SIZE)
    food = jnp.where(on_food[:, None], new_food, state.food)
    food_alive = food_alive | on_food
    on_hazard = (agent == state.hazard).all()
    reward = reward - on_hazard.astype(jnp.float32)
    t = state.t + 1
    done = on_hazard | (t >= MAX_STEPS)
    state = GridState(agent, food, food_alive, state.hazard, t)
    return state, _obs(state), reward, done


def make() -> Env:
    return Env(reset=_reset, step=auto_reset(_reset, _step),
               num_actions=NUM_ACTIONS, obs_shape=(SIZE, SIZE, 4))
