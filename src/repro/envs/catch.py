"""Catch (bsuite-style): a ball falls down a ROWSxCOLS board; the paddle on
the bottom row moves left/stay/right. Reward +1 on catch, -1 on miss, at the
final row only. Observation: (ROWS, COLS, 1) float32 with ball and paddle
pixels set to 1.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.base import Env, auto_reset

ROWS, COLS = 10, 5
NUM_ACTIONS = 3


class CatchState(NamedTuple):
    ball_x: jnp.ndarray
    ball_y: jnp.ndarray
    paddle_x: jnp.ndarray


def _obs(state):
    board = jnp.zeros((ROWS, COLS), jnp.float32)
    board = board.at[state.ball_y, state.ball_x].set(1.0)
    board = board.at[ROWS - 1, state.paddle_x].set(1.0)
    return board[..., None]


def _reset(key):
    ball_x = jax.random.randint(key, (), 0, COLS)
    state = CatchState(ball_x, jnp.zeros((), jnp.int32),
                       jnp.asarray(COLS // 2, jnp.int32))
    return state, _obs(state)


def _step(state, action, key):
    del key
    dx = action - 1  # 0,1,2 -> -1,0,1
    paddle_x = jnp.clip(state.paddle_x + dx, 0, COLS - 1)
    ball_y = state.ball_y + 1
    state = CatchState(state.ball_x, ball_y, paddle_x)
    done = ball_y == ROWS - 1
    reward = jnp.where(
        done, jnp.where(state.ball_x == paddle_x, 1.0, -1.0), 0.0)
    return state, _obs(state), reward.astype(jnp.float32), done


def make() -> Env:
    return Env(reset=_reset, step=auto_reset(_reset, _step),
               num_actions=NUM_ACTIONS, obs_shape=(ROWS, COLS, 1))
