"""Functional environment interface (pure JAX, vmap/scan-able).

An Env is a pair of pure functions:
  reset(key)                 -> (state, obs)
  step(state, action, key)   -> (state, obs, reward, done)

Auto-reset semantics: when an episode ends, ``step`` returns done=True and
the obs of the freshly reset episode (standard vectorised-RL convention, and
what IMPALA's end-of-life episode definition needs).

Host-loop (MonoBeast-style) code wraps these with ``HostEnv`` which holds
state imperatively and matches the OpenAI Gym step/reset API used by
TorchBeast's polybeast_env.py.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Env(NamedTuple):
    reset: Callable[[Any], Tuple[Any, jnp.ndarray]]
    step: Callable[[Any, jnp.ndarray, Any], Tuple[Any, jnp.ndarray,
                                                  jnp.ndarray, jnp.ndarray]]
    num_actions: int
    obs_shape: Tuple[int, ...]


def auto_reset(env_reset, env_step):
    """Wrap a (reset, step) pair so that done -> fresh episode obs/state."""
    def step(state, action, key):
        k1, k2 = jax.random.split(key)
        new_state, obs, reward, done = env_step(state, action, k1)
        reset_state, reset_obs = env_reset(k2)
        state = jax.tree.map(lambda a, b: jnp.where(done, b, a),
                             new_state, reset_state)
        obs = jnp.where(done, reset_obs, obs)
        return state, obs, reward, done
    return step


class HostEnv:
    """Imperative Gym-like wrapper over a functional Env (one episode stream).

    This is the object served by the paper's environment servers; here it
    backs the MonoBeast-style host actor loop.
    """

    def __init__(self, env: Env, seed: int = 0):
        self._env = env
        self._key = jax.random.PRNGKey(seed)
        self._state = None
        self._step = jax.jit(env.step)
        self._reset = jax.jit(env.reset)

    @property
    def num_actions(self):
        return self._env.num_actions

    def reset(self):
        self._key, k = jax.random.split(self._key)
        self._state, obs = self._reset(k)
        return jax.device_get(obs)

    def step(self, action):
        self._key, k = jax.random.split(self._key)
        self._state, obs, reward, done = self._step(
            self._state, jnp.asarray(action), k)
        return (jax.device_get(obs), float(reward), bool(done), {})
