from repro.envs import base, catch, gridworld, token_mdp  # noqa: F401
