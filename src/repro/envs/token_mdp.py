"""Token-MDP: a dense-reward sequence-generation environment for LLM-policy
IMPALA (the RL-finetuning setting of DESIGN.md §2).

State is the current token. The environment rewards emitting the token
``(a * prev + b) mod V`` (a hidden affine chain): +1 for the correct next
token, 0 otherwise. Episodes last EP_LEN steps. A policy must learn the
prev->next mapping — learnable from scratch by a small decoder, and a
shape-compatible stand-in for reward-model-scored generation.

Observation = current token id (the driver feeds the *sequence so far* to
the transformer; the env itself is Markov in the last token).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.base import Env, auto_reset

EP_LEN = 32


class TokenState(NamedTuple):
    token: jnp.ndarray  # () int32
    t: jnp.ndarray      # () int32


def make(vocab_size: int, a: int = 5, b: int = 3, ep_len: int = EP_LEN) -> Env:
    def _obs(state):
        return state.token

    def _reset(key):
        token = jax.random.randint(key, (), 0, vocab_size)
        state = TokenState(token, jnp.zeros((), jnp.int32))
        return state, _obs(state)

    def _step(state, action, key):
        del key
        target = (a * state.token + b) % vocab_size
        reward = (action == target).astype(jnp.float32)
        t = state.t + 1
        done = t >= ep_len
        state = TokenState(action.astype(jnp.int32), t)
        return state, _obs(state), reward, done

    return Env(reset=_reset, step=auto_reset(_reset, _step),
               num_actions=vocab_size, obs_shape=())
