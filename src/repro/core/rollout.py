"""On-device rollout generation (the PolyBeast->TPU adaptation).

Instead of gRPC environment servers feeding C++ actor threads, the
environments are pure JAX and the whole actor loop — policy evaluation,
action sampling, env step — runs inside one compiled ``lax.scan``
(Podracer/Anakin style). Batched over B envs with vmap; distributed over
the mesh data axis by the launcher.

The rollout layout matches the paper's learner-input dict (§2): time-major
(T+1 obs; T actions/rewards/dones/behavior outputs), so the learner code is
identical for host-loop and on-device actors.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def make_unroll(env, agent_apply, unroll_length: int):
    """Build unroll(params, carry, key) -> (carry, rollout).

    carry = (env_state, obs) batched over B. rollout dict:
      obs             (T+1, B, *obs_shape)
      action          (T, B) int32
      behavior_logits (T, B, A) float32
      reward, done    (T, B)
    """
    v_step = jax.vmap(env.step, in_axes=(0, 0, 0))

    def unroll(params, carry, key):
        def one_step(carry, key):
            env_state, obs = carry
            out = agent_apply(params, obs)
            b = obs.shape[0]
            action = jax.random.categorical(key, out.policy_logits)
            keys = jax.random.split(jax.random.fold_in(key, 1), b)
            env_state, next_obs, reward, done = v_step(env_state, action,
                                                       keys)
            step_data = {
                "obs": obs,
                "action": action.astype(jnp.int32),
                "behavior_logits": out.policy_logits,
                "reward": reward,
                "done": done,
            }
            return (env_state, next_obs), step_data

        keys = jax.random.split(key, unroll_length)
        carry, traj = jax.lax.scan(one_step, carry, keys)
        rollout = {
            "obs": jnp.concatenate([traj["obs"], carry[1][None]], axis=0),
            "action": traj["action"],
            "behavior_logits": traj["behavior_logits"],
            "reward": traj["reward"],
            "done": traj["done"],
        }
        return carry, rollout

    return unroll


def env_reset_batch(env, key, batch: int):
    keys = jax.random.split(key, batch)
    state, obs = jax.vmap(env.reset)(keys)
    return state, obs


def episode_returns(rollout) -> Dict[str, jnp.ndarray]:
    """Diagnostics: per-batch mean reward and episode termination count."""
    return {
        "reward_per_step": rollout["reward"].mean(),
        "episodes_ended": rollout["done"].sum(),
    }


def make_recurrent_unroll(env, agent_apply, agent_initial_state,
                          unroll_length: int):
    """Recurrent-agent unroll (TorchBeast core_state contract): the actor
    threads the LSTM state through the episode, resets it on done, and the
    rollout records the INITIAL core_state so the learner can re-run the
    recurrence from the same point.

    carry = (env_state, obs, core_state); rollout adds "core_state" (the
    state at the start of the unroll) and "done" is consumed by the agent
    to zero its state mid-unroll.
    """
    v_step = jax.vmap(env.step, in_axes=(0, 0, 0))

    def initial_carry(env_state, obs, batch):
        return (env_state, obs, agent_initial_state(batch),
                jnp.zeros((batch,), bool))

    def unroll(params, carry, key):
        env_state, obs, core_state, done0 = carry
        initial_core = core_state

        def one_step(c, key):
            env_state, obs, core_state, pre_done = c
            out = agent_apply(params, obs, core_state, pre_done)
            b = obs.shape[0]
            action = jax.random.categorical(key, out.policy_logits)
            keys = jax.random.split(jax.random.fold_in(key, 1), b)
            env_state, next_obs, reward, next_done = v_step(
                env_state, action, keys)
            step_data = {
                "obs": obs,
                "pre_done": pre_done,  # obs[t] starts a fresh episode
                "action": action.astype(jnp.int32),
                "behavior_logits": out.policy_logits,
                "reward": reward,
                "done": next_done,     # episode ended on this transition
            }
            return (env_state, next_obs, out.core_state, next_done), \
                step_data

        keys = jax.random.split(key, unroll_length)
        (env_state, obs, core_state, done), traj = jax.lax.scan(
            one_step, (env_state, obs, core_state, done0), keys)
        rollout = {
            "obs": jnp.concatenate([traj["obs"], obs[None]], axis=0),
            "pre_done": jnp.concatenate([traj["pre_done"], done[None]],
                                        axis=0),
            "action": traj["action"],
            "behavior_logits": traj["behavior_logits"],
            "reward": traj["reward"],
            "done": traj["done"],
            "core_state": initial_core,
        }
        return (env_state, obs, core_state, done), rollout

    unroll.initial_carry = initial_carry
    return unroll
