"""IMPALA losses (policy gradient + baseline + entropy), plus the
chunked-vocab variants needed at LLM scale (the (T,B,V) logits tensor for
V=150k does not fit; we scan over sequence chunks).

Loss definitions match TorchBeast's polybeast.py:
  pg_loss       = sum_t  -log pi(a_t|s_t) * stop_grad(pg_advantage_t)
  baseline_loss = 0.5 * sum_t (vs_t - V(s_t))^2
  entropy_loss  = sum_t sum_a pi log pi          (i.e. negative entropy)
  total = pg + baseline_cost * baseline + entropy_cost * entropy
All sums over T and mean... TorchBeast sums over (T, B); we keep SUM over T
and MEAN over B (configurable via ``reduce``) — the sum convention is the
paper's, recorded in EXPERIMENTS.md §Validation.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import vtrace as vtrace_lib


class ImpalaLossOutput(NamedTuple):
    total: jnp.ndarray
    pg_loss: jnp.ndarray
    baseline_loss: jnp.ndarray
    entropy_loss: jnp.ndarray
    vs_mean: jnp.ndarray
    rho_mean: jnp.ndarray
    # per-column mean |pg_advantage| — the elite-replay priority signal
    priority: jnp.ndarray = 0.0
    clear_policy_loss: jnp.ndarray = 0.0
    clear_value_loss: jnp.ndarray = 0.0


def _reduce(x, reduce):
    return jnp.sum(x) if reduce == "sum" else jnp.sum(jnp.mean(x, axis=1))


def _vtrace_fn(vtrace_impl):
    """Resolve the V-trace recursion implementation: the reverse-scan
    reference ('scan') or the Pallas TPU kernel ('kernel',
    kernels/vtrace.py — interpret-mode on CPU, same recursion blocked over
    128-wide batch lanes held in VMEM)."""
    if vtrace_impl == "scan":
        return vtrace_lib.vtrace_from_importance_weights
    if vtrace_impl == "kernel":
        from repro.kernels import ops
        return ops.vtrace_from_importance_weights_kernel
    raise ValueError(f"vtrace_impl must be 'scan' or 'kernel': "
                     f"{vtrace_impl!r}")


def clear_auxiliary_loss(target_lp_all, behavior_logits, values,
                         behavior_values, is_replay, *, reduce="mean"):
    """CLEAR-style behavioral + value cloning on replayed rows only
    (Rolnick et al. 2019, "Experience Replay for Continual Learning"):

      policy cloning  sum_t KL(mu || pi)         — keep pi close to the
                                                   behavior policy that
                                                   generated the replayed
                                                   data
      value cloning   0.5 * sum_t (V_mu - V)^2   — anchor V on the value
                                                   estimates RECORDED when
                                                   the data was generated
                                                   (behavior_values; None
                                                   disables the term)

    is_replay: (B,) bool column mask; fresh rows contribute nothing.
    target_lp_all/values carry gradients; behavior_logits/behavior_values
    are data.
    """
    behavior_lp = jax.nn.log_softmax(
        behavior_logits.astype(jnp.float32), -1)
    kl = jnp.sum(jnp.exp(behavior_lp) * (behavior_lp - target_lp_all),
                 axis=-1)                                   # (T, B)
    mask = is_replay.astype(jnp.float32)[None, :]           # (1, B)
    policy_cloning = _reduce(kl * mask, reduce)
    value_cloning = jnp.zeros(())
    if behavior_values is not None:
        value_cloning = 0.5 * _reduce(
            jnp.square(behavior_values - values) * mask, reduce)
    return policy_cloning, value_cloning


def impala_loss_from_logits(target_logits, behavior_logits, actions,
                            rewards, discounts, values, bootstrap_value,
                            *, baseline_cost=0.5, entropy_cost=0.01,
                            clip_rho=1.0, clip_c=1.0, reduce="mean",
                            is_replay=None, behavior_values=None,
                            clear_policy_cost=0.0, clear_value_cost=0.0,
                            vtrace_impl="scan"):
    """Paper-faithful path (full logits, small action spaces). All (T,B,...).

    target_logits/values carry gradients; behavior_* are data.
    is_replay: optional (B,) bool mask of replayed columns; when given
    together with nonzero clear_*_cost, the CLEAR cloning terms are added
    for those columns (core/replay.py). behavior_values (T,B): the acting
    network's value estimates recorded at generation time — the
    value-cloning anchor (without it only policy cloning is applied).
    vtrace_impl: 'scan' (reverse-scan reference) or 'kernel' (the Pallas
    V-trace recursion, interpret-mode on CPU).
    """
    target_lp_all = jax.nn.log_softmax(target_logits.astype(jnp.float32), -1)
    target_lp = jnp.take_along_axis(target_lp_all, actions[..., None],
                                    axis=-1)[..., 0]
    behavior_lp = vtrace_lib._action_log_probs(behavior_logits, actions)

    vt = _vtrace_fn(vtrace_impl)(
        jax.lax.stop_gradient(target_lp) - behavior_lp, discounts, rewards,
        jax.lax.stop_gradient(values), bootstrap_value,
        clip_rho_threshold=clip_rho, clip_c_threshold=clip_c)

    pg_loss = _reduce(-target_lp * vt.pg_advantages, reduce)
    baseline_loss = 0.5 * _reduce(jnp.square(vt.vs - values), reduce)
    probs = jnp.exp(target_lp_all)
    entropy_loss = _reduce(jnp.sum(probs * target_lp_all, axis=-1), reduce)

    total = pg_loss + baseline_cost * baseline_loss \
        + entropy_cost * entropy_loss

    clear_pc = clear_vc = jnp.zeros(())
    if is_replay is not None and (clear_policy_cost or clear_value_cost):
        clear_pc, clear_vc = clear_auxiliary_loss(
            target_lp_all, behavior_logits, values, behavior_values,
            is_replay, reduce=reduce)
        total = total + clear_policy_cost * clear_pc \
            + clear_value_cost * clear_vc

    rho = jnp.exp(jax.lax.stop_gradient(target_lp) - behavior_lp)
    priority = jnp.mean(jnp.abs(vt.pg_advantages), axis=0)     # (B,)
    return ImpalaLossOutput(total, pg_loss, baseline_loss, entropy_loss,
                            vt.vs.mean(), rho.mean(), priority,
                            clear_pc, clear_vc)


def impala_loss_from_logprobs(target_logprobs, target_entropy,
                              behavior_logprobs, rewards, discounts, values,
                              bootstrap_value, *, baseline_cost=0.5,
                              entropy_cost=0.01, clip_rho=1.0, clip_c=1.0,
                              reduce="mean", vtrace_impl="scan"):
    """LLM-scale path: (T,B) chosen-action log-probs + per-step entropy
    (computed chunked by the caller). target_logprobs/values/target_entropy
    carry gradients. vtrace_impl as in ``impala_loss_from_logits``."""
    vt = _vtrace_fn(vtrace_impl)(
        jax.lax.stop_gradient(target_logprobs) - behavior_logprobs,
        discounts, rewards, jax.lax.stop_gradient(values), bootstrap_value,
        clip_rho_threshold=clip_rho, clip_c_threshold=clip_c)
    pg_loss = _reduce(-target_logprobs * vt.pg_advantages, reduce)
    baseline_loss = 0.5 * _reduce(jnp.square(vt.vs - values), reduce)
    entropy_loss = _reduce(-target_entropy, reduce)
    total = pg_loss + baseline_cost * baseline_loss \
        + entropy_cost * entropy_loss
    rho = jnp.exp(jax.lax.stop_gradient(target_logprobs) - behavior_logprobs)
    priority = jnp.mean(jnp.abs(vt.pg_advantages), axis=0)     # (B,)
    return ImpalaLossOutput(total, pg_loss, baseline_loss, entropy_loss,
                            vt.vs.mean(), rho.mean(), priority)


# ---------------------------------------------------------------------------
# chunked vocab head: per-token log-prob of chosen action + entropy
# ---------------------------------------------------------------------------

def chunked_logprob_entropy(hidden, unembed, actions, *, chunk=512,
                            final_softcap=None):
    """hidden: (B,S,d); unembed: (d,V); actions: (B,S) int32.

    Scans over S-chunks so the (B,chunk,V) logits stay transient.
    Returns (logprob (B,S), entropy (B,S)) — both differentiable.
    """
    b, s, d = hidden.shape
    c = min(chunk, s)
    assert s % c == 0
    n = s // c
    hs = hidden.reshape(b, n, c, d).transpose(1, 0, 2, 3)
    ac = actions.reshape(b, n, c).transpose(1, 0, 2)

    @jax.checkpoint
    def step(_, xs):
        # checkpointed: the (B,chunk,V) logits/log-softmax are recomputed in
        # the backward pass instead of being stored for every chunk.
        h, a = xs
        logits = jnp.einsum("bcd,dv->bcv", h, unembed.astype(h.dtype),
                            preferred_element_type=jnp.float32)
        if final_softcap:
            logits = final_softcap * jnp.tanh(logits / final_softcap)
        lp = jax.nn.log_softmax(logits, axis=-1)
        alp = jnp.take_along_axis(lp, a[..., None], axis=-1)[..., 0]
        ent = -jnp.sum(jnp.exp(lp) * lp, axis=-1)
        return None, (alp, ent)

    _, (lps, ents) = jax.lax.scan(step, None, (hs, ac))
    return (lps.transpose(1, 0, 2).reshape(b, s),
            ents.transpose(1, 0, 2).reshape(b, s))


def chunked_softmax_xent(hidden, unembed, labels, *, chunk=512,
                         final_softcap=None):
    """Standard LM cross-entropy, chunked over S. Returns mean nats/token."""
    lp, _ = chunked_logprob_entropy(hidden, unembed, labels, chunk=chunk,
                                    final_softcap=final_softcap)
    return -lp.mean()
