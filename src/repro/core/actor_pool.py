"""Host-loop actor pool — the MonoBeast/PolyBeast actor architecture in
Python threads over functional JAX envs.

Each actor thread runs its environment copy, sends observations through the
shared DynamicBatcher (the inference queue; evaluated centrally in batch),
accumulates unroll_length transitions, and puts the rollout into the
BatchingQueue (the learner queue). An inference thread drains the
DynamicBatcher with the jitted policy — mirroring polybeast.py's
``inference_thread`` — and the learner iterates the BatchingQueue.

This path exists for environments that cannot be compiled (the paper's
Atari case). The compiled alternative is core/rollout.py (DESIGN.md §1).
"""

from __future__ import annotations

import threading
from typing import Callable, List

import numpy as np

from repro.core.batcher import BatchingQueue, Closed, DynamicBatcher
from repro.envs.base import HostEnv


class ActorPool:
    def __init__(self, env_fn: Callable[[int], HostEnv], num_actors: int,
                 unroll_length: int, inference: DynamicBatcher,
                 learner_queue: BatchingQueue, seed: int = 0):
        self.env_fn = env_fn
        self.num_actors = num_actors
        self.unroll_length = unroll_length
        self.inference = inference
        self.learner_queue = learner_queue
        self.seed = seed
        self._threads: List[threading.Thread] = []
        self._stop = threading.Event()
        self.steps = 0  # total env frames (for FPS accounting)
        self._steps_lock = threading.Lock()

    def _actor_loop(self, idx: int):
        env = self.env_fn(self.seed + idx)
        rng = np.random.default_rng(self.seed + idx)
        obs = env.reset()
        try:
            while not self._stop.is_set():
                traj = {"obs": [obs], "action": [], "behavior_logits": [],
                        "reward": [], "done": []}
                for _ in range(self.unroll_length):
                    logits = self.inference.compute(
                        np.asarray(obs, np.float32))
                    # sample on the actor (host) side via Gumbel-max
                    u = rng.gumbel(size=logits.shape)
                    action = int(np.argmax(logits + u))
                    obs, reward, done, _ = env.step(action)
                    traj["obs"].append(obs)
                    traj["action"].append(action)
                    traj["behavior_logits"].append(logits)
                    traj["reward"].append(reward)
                    traj["done"].append(done)
                rollout = {
                    "obs": np.stack(traj["obs"]).astype(np.float32),
                    "action": np.asarray(traj["action"], np.int32),
                    "behavior_logits": np.stack(traj["behavior_logits"]),
                    "reward": np.asarray(traj["reward"], np.float32),
                    "done": np.asarray(traj["done"], bool),
                }
                self.learner_queue.put(rollout)
                with self._steps_lock:
                    self.steps += self.unroll_length
        except Closed:
            pass

    def start(self):
        for i in range(self.num_actors):
            t = threading.Thread(target=self._actor_loop, args=(i,),
                                 daemon=True, name=f"actor-{i}")
            t.start()
            self._threads.append(t)

    def stop(self):
        self._stop.set()
        self.inference.close()
        self.learner_queue.close()
        for t in self._threads:
            t.join(timeout=5.0)


def start_inference_thread(batcher: DynamicBatcher, policy_fn) -> threading.Thread:
    """polybeast.py's ``infer``: drain the inference queue with the jitted
    policy. policy_fn: (B, *obs) -> (B, A) logits (numpy in/out)."""
    def loop():
        while True:
            try:
                got = batcher.get_batch(timeout=1.0)
            except Closed:
                return
            if got is None:
                continue
            obs, respond, _ = got
            respond(np.asarray(policy_fn(obs)))

    t = threading.Thread(target=loop, daemon=True, name="inference")
    t.start()
    return t
