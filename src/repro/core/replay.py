"""Off-policy replay buffers over canonical time-major rollouts.

rlpyt and TorchRL treat the replay buffer as a first-class, swappable
component of the training loop; this module gives the Runtime the same
capability. A ``ReplayBuffer`` stores *individual rollouts* (one batch
column of the canonical time-major layout in core/sources.py) in
preallocated numpy slots with free-list recycling — the same zero-copy
scheme as ``core/rollout_buffers.py`` — and hands back stacked
``(T, k, ...)`` batches whose stored ``behavior_logits`` keep V-trace
importance weights correct for replayed data.

Three strategies:

  ``UniformReplay``    — FIFO eviction, uniform sampling (vanilla ER).
  ``EliteReplay``      — priority = per-rollout V-trace advantage magnitude
                         (fed back from the learner step), sampling ∝
                         priority and evicting the LOWEST-priority rollout
                         first (the elite-buffer V-trace variant).
  ``AttentiveReplay``  — FIFO eviction, but sampling returns the rollouts
                         whose observations are *closest* to the current
                         fresh batch (mean-observation feature distance),
                         so replayed data stays near the learner's current
                         state distribution.

The learner feeds priorities back through
``ReplaySource.on_learner_metrics`` (core/sources.py): the train step
emits a per-column ``priority`` metric (mean |pg_advantage|), and the
source routes it to ``update_priorities`` for every slot that contributed
to the batch.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Protocol, Tuple, \
    runtime_checkable

import numpy as np

Rollout = Dict[str, Any]


@runtime_checkable
class ReplayBuffer(Protocol):
    """The strategy contract ``ReplaySource`` composes over.

    ``insert`` splits a canonical time-major rollout batch into its B
    columns and stores each in a recycled slot (evicting per strategy when
    full), returning the slot ids in column order. ``sample`` returns a
    stacked ``(T, k, ...)`` rollout plus the slot ids it was drawn from.
    ``update_priorities`` is the learner feedback path.
    """

    capacity: int

    def insert(self, rollout: Rollout,
               priorities: Optional[np.ndarray] = None) -> List[int]: ...

    def sample(self, k: int, rng: np.random.Generator, *,
               query: Optional[Any] = None) -> Tuple[Rollout, List[int]]: ...

    def update_priorities(self, slot_ids, priorities) -> None: ...

    def __len__(self) -> int: ...

    def stats(self) -> Dict[str, float]: ...

    def clear(self) -> None: ...


def _obs_feature(obs_col: np.ndarray) -> np.ndarray:
    """Mean-over-time flattened observation — the similarity feature the
    attentive strategy matches on. obs_col: (T+1, *obs_shape)."""
    x = np.asarray(obs_col, np.float32)
    return x.reshape(x.shape[0], -1).mean(axis=0)


class _SlotReplay:
    """Shared slot machinery: preallocated per-key arrays, a free list, and
    per-slot metadata (priority, insertion sequence, obs feature)."""

    # set on strategies whose sampling consumes the fresh-batch query;
    # ReplaySource skips the host-side obs copy for the others.
    needs_query = False

    def __init__(self, capacity: int):
        assert capacity > 0
        self.capacity = capacity
        self._arrays: Optional[Dict[str, np.ndarray]] = None
        self._free: List[int] = list(range(capacity))
        self._live = np.zeros(capacity, bool)
        self._prio = np.zeros(capacity, np.float64)
        self._seq = np.zeros(capacity, np.int64)
        self._feat: Optional[np.ndarray] = None
        self._next_seq = 0
        # insert/sample hand out *tickets* (the insertion sequence number),
        # not raw slot indices: a slot recycled between sample and the
        # learner's priority feedback must not have the new occupant's
        # priority clobbered by a stale update.
        self._slot_of_ticket: Dict[int, int] = {}
        self.inserted = 0
        self.evicted = 0
        self.sampled = 0

    # -- allocation ----------------------------------------------------------

    def _allocate(self, rollout: Rollout) -> None:
        """Lazily size the slot arrays from the first rollout batch: key ->
        (capacity, T(+1), *feature_shape) — column i of the batch is
        ``x[:, i]`` (batch dim is axis 1 in the canonical layout)."""
        self._arrays = {}
        for k, v in rollout.items():
            v = np.asarray(v)
            col_shape = (v.shape[0],) + v.shape[2:]
            self._arrays[k] = np.empty((self.capacity,) + col_shape, v.dtype)
        obs = np.asarray(rollout["obs"])
        self._feat = np.zeros(
            (self.capacity, int(np.prod(obs.shape[2:]) or 1)), np.float32)

    # -- eviction (strategy hook) -------------------------------------------

    def _victim(self) -> int:
        """Pick the slot to evict when full. Default: oldest (FIFO)."""
        live = np.flatnonzero(self._live)
        return int(live[np.argmin(self._seq[live])])

    def _evict(self) -> None:
        slot = self._victim()
        self._live[slot] = False
        self._slot_of_ticket.pop(int(self._seq[slot]), None)
        self._free.append(slot)
        self.evicted += 1

    # -- actor side ----------------------------------------------------------

    def insert(self, rollout: Rollout,
               priorities: Optional[np.ndarray] = None) -> List[int]:
        if self._arrays is None:
            self._allocate(rollout)
        host = {k: np.asarray(v) for k, v in rollout.items()}
        b = host["action"].shape[1]
        # Optimistic default: fresh rollouts enter at the current max
        # priority so elite sampling visits them at least once before the
        # learner has scored them (the standard PER initialisation).
        default_prio = float(self._prio[self._live].max()) \
            if self._live.any() else 1.0
        ids: List[int] = []
        for i in range(b):
            if not self._free:
                self._evict()
            slot = self._free.pop()
            try:
                for k, arr in self._arrays.items():
                    arr[slot][...] = host[k][:, i]
                self._feat[slot] = _obs_feature(host["obs"][:, i])
            except Exception:
                # Never leak the slot if a malformed rollout dies mid-write.
                self._free.append(slot)
                raise
            self._live[slot] = True
            self._prio[slot] = (default_prio if priorities is None
                                else float(priorities[i]))
            self._seq[slot] = self._next_seq
            self._slot_of_ticket[self._next_seq] = slot
            ids.append(self._next_seq)
            self._next_seq += 1
            self.inserted += 1
        return ids

    # -- learner side ---------------------------------------------------------

    def _choose(self, live: np.ndarray, k: int,
                rng: np.random.Generator,
                query: Optional[Any]) -> np.ndarray:
        """Strategy hook: pick k slot ids from the live set."""
        return rng.choice(live, size=k, replace=len(live) < k)

    def sample(self, k: int, rng: np.random.Generator, *,
               query: Optional[Any] = None) -> Tuple[Rollout, List[int]]:
        live = np.flatnonzero(self._live)
        if len(live) == 0:
            raise ValueError("sample() from an empty replay buffer")
        slots = self._choose(live, k, rng, query)
        batch = {key: np.stack([arr[i] for i in slots], axis=1)
                 for key, arr in self._arrays.items()}
        self.sampled += k
        return batch, [int(self._seq[i]) for i in slots]

    def update_priorities(self, slot_ids, priorities) -> None:
        priorities = np.asarray(priorities, np.float64)
        for i, ticket in enumerate(slot_ids):
            slot = self._slot_of_ticket.get(int(ticket))
            if slot is not None:  # evicted/recycled since sampling: ignore
                self._prio[slot] = priorities[i]

    # -- introspection ---------------------------------------------------------

    def __len__(self) -> int:
        return int(self._live.sum())

    def stats(self) -> Dict[str, float]:
        n = len(self)
        return {
            "occupancy": n / self.capacity,
            "mean_priority": float(self._prio[self._live].mean()) if n else 0.0,
            "inserted": float(self.inserted),
            "evicted": float(self.evicted),
            "sampled": float(self.sampled),
        }

    def clear(self) -> None:
        """Return every slot to the free list (drops contents)."""
        self._live[:] = False
        self._slot_of_ticket.clear()
        self._free = list(range(self.capacity))


class UniformReplay(_SlotReplay):
    """FIFO eviction, uniform sampling."""


class EliteReplay(_SlotReplay):
    """Keep what the learner found surprising: sampling ∝ priority^alpha,
    eviction kills the lowest-priority (oldest on ties) rollout."""

    def __init__(self, capacity: int, *, alpha: float = 1.0,
                 min_priority: float = 1e-3):
        super().__init__(capacity)
        self.alpha = alpha
        self.min_priority = min_priority

    def _victim(self) -> int:
        live = np.flatnonzero(self._live)
        # lexsort: lowest priority first, oldest first among equals
        order = np.lexsort((self._seq[live], self._prio[live]))
        return int(live[order[0]])

    def _choose(self, live, k, rng, query):
        p = np.maximum(self._prio[live], self.min_priority) ** self.alpha
        return rng.choice(live, size=k, replace=len(live) < k, p=p / p.sum())

    def update_priorities(self, slot_ids, priorities) -> None:
        priorities = np.maximum(np.asarray(priorities, np.float64),
                                self.min_priority)
        super().update_priorities(slot_ids, priorities)


class AttentiveReplay(_SlotReplay):
    """FIFO eviction; sampling returns the k stored rollouts whose
    mean-observation feature is nearest the query batch's (deterministic
    given buffer contents and query)."""

    needs_query = True

    def _choose(self, live, k, rng, query):
        if query is None:  # no query -> uniform fallback
            return super()._choose(live, k, rng, query)
        q = np.asarray(query, np.float32)
        # query is a full (T+1, B, *obs) fresh batch: average its columns
        qf = np.stack([_obs_feature(q[:, i]) for i in range(q.shape[1])]
                      ).mean(axis=0)
        d = np.linalg.norm(self._feat[live] - qf[None, :], axis=1)
        order = live[np.argsort(d, kind="stable")]
        reps = -(-k // len(order))  # ceil: wrap when k > live
        return np.tile(order, reps)[:k]


_KINDS = {"uniform": UniformReplay, "elite": EliteReplay,
          "attentive": AttentiveReplay}


def make_buffer(kind: str, capacity: int, **kwargs) -> ReplayBuffer:
    """Factory behind the ``--replay {uniform,elite,attentive}`` flag."""
    try:
        cls = _KINDS[kind]
    except KeyError:
        raise ValueError(f"unknown replay kind {kind!r}; "
                         f"choose from {sorted(_KINDS)}") from None
    return cls(capacity, **kwargs)
