"""Off-policy replay buffers over canonical time-major rollouts.

rlpyt and TorchRL treat the replay buffer as a first-class, swappable
component of the training loop; this module gives the Runtime the same
capability. A ``ReplayBuffer`` stores *individual rollouts* (one batch
column of the canonical time-major layout in core/sources.py) in
preallocated numpy slots with free-list recycling — the same zero-copy
scheme as ``core/rollout_buffers.py`` — and hands back stacked
``(T, k, ...)`` batches whose stored ``behavior_logits`` keep V-trace
importance weights correct for replayed data.

Three strategies:

  ``UniformReplay``    — FIFO eviction, uniform sampling (vanilla ER).
  ``EliteReplay``      — priority = per-rollout V-trace advantage magnitude
                         (fed back from the learner step), sampling ∝
                         priority and evicting the LOWEST-priority rollout
                         first (the elite-buffer V-trace variant).
  ``AttentiveReplay``  — FIFO eviction, but sampling returns the rollouts
                         whose observations are *closest* to the current
                         fresh batch (mean-observation feature distance),
                         so replayed data stays near the learner's current
                         state distribution.

The learner feeds priorities back through
``ReplaySource.on_learner_metrics`` (core/sources.py): the train step
emits a per-column ``priority`` metric (mean |pg_advantage|), and the
source routes it to ``update_priorities`` for every slot that contributed
to the batch.

``ShardedReplay`` composes any strategy with the data-parallel learner
(``--mesh-data N --replay ...``): slot storage is PARTITIONED per mesh
device (one strategy buffer per device, holding only that device's batch
columns), and sampled columns are re-assembled into a globally-sharded
batch with ``jax.make_array_from_single_device_arrays`` — each device
receives only its own slice, so the hot path never concatenates or
re-shards the global batch on the host.

Buffers are stateful, checkpointable objects: ``state_dict()`` /
``load_state_dict()`` capture slots, priorities, tickets and counters, so
a resumed run replays exactly what the uninterrupted run would have
(the SourceState protocol of core/sources.py).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Protocol, Tuple, \
    runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

Rollout = Dict[str, Any]


@runtime_checkable
class ReplayBuffer(Protocol):
    """The strategy contract ``ReplaySource`` composes over.

    ``insert`` splits a canonical time-major rollout batch into its B
    columns and stores each in a recycled slot (evicting per strategy when
    full), returning the slot ids in column order. ``sample`` returns a
    stacked ``(T, k, ...)`` rollout plus the slot ids it was drawn from.
    ``update_priorities`` is the learner feedback path.
    ``state_dict``/``load_state_dict`` checkpoint the buffer (slots,
    priorities, tickets) for the SourceState resume protocol.
    """

    capacity: int

    def insert(self, rollout: Rollout,
               priorities: Optional[np.ndarray] = None) -> List[int]: ...

    def sample(self, k: int, rng: np.random.Generator, *,
               query: Optional[Any] = None) -> Tuple[Rollout, List[int]]: ...

    def update_priorities(self, slot_ids, priorities) -> None: ...

    def __len__(self) -> int: ...

    def stats(self) -> Dict[str, float]: ...

    def clear(self) -> None: ...

    def state_dict(self) -> Dict[str, Any]: ...

    def load_state_dict(self, state: Dict[str, Any]) -> None: ...


def _obs_feature(obs_col: np.ndarray) -> np.ndarray:
    """Mean-over-time flattened observation — the similarity feature the
    attentive strategy matches on. obs_col: (T+1, *obs_shape)."""
    x = np.asarray(obs_col, np.float32)
    return x.reshape(x.shape[0], -1).mean(axis=0)


class _SlotReplay:
    """Shared slot machinery: preallocated per-key arrays, a free list, and
    per-slot metadata (priority, insertion sequence, obs feature)."""

    # set on strategies whose sampling consumes the fresh-batch query;
    # ReplaySource skips the host-side obs copy for the others.
    needs_query = False

    def __init__(self, capacity: int):
        assert capacity > 0
        self.capacity = capacity
        self._arrays: Optional[Dict[str, np.ndarray]] = None
        self._free: List[int] = list(range(capacity))
        self._live = np.zeros(capacity, bool)
        self._prio = np.zeros(capacity, np.float64)
        self._seq = np.zeros(capacity, np.int64)
        self._feat: Optional[np.ndarray] = None
        self._next_seq = 0
        # insert/sample hand out *tickets* (the insertion sequence number),
        # not raw slot indices: a slot recycled between sample and the
        # learner's priority feedback must not have the new occupant's
        # priority clobbered by a stale update.
        self._slot_of_ticket: Dict[int, int] = {}
        self.inserted = 0
        self.evicted = 0
        self.sampled = 0

    # -- allocation ----------------------------------------------------------

    def _allocate(self, rollout: Rollout) -> None:
        """Lazily size the slot arrays from the first rollout batch: key ->
        (capacity, T(+1), *feature_shape) — column i of the batch is
        ``x[:, i]`` (batch dim is axis 1 in the canonical layout)."""
        self._arrays = {}
        for k, v in rollout.items():
            v = np.asarray(v)
            col_shape = (v.shape[0],) + v.shape[2:]
            self._arrays[k] = np.empty((self.capacity,) + col_shape, v.dtype)
        obs = np.asarray(rollout["obs"])
        self._feat = np.zeros(
            (self.capacity, int(np.prod(obs.shape[2:]) or 1)), np.float32)

    # -- eviction (strategy hook) -------------------------------------------

    def _victim(self) -> int:
        """Pick the slot to evict when full. Default: oldest (FIFO)."""
        live = np.flatnonzero(self._live)
        return int(live[np.argmin(self._seq[live])])

    def _evict(self) -> None:
        slot = self._victim()
        self._live[slot] = False
        self._slot_of_ticket.pop(int(self._seq[slot]), None)
        self._free.append(slot)
        self.evicted += 1

    # -- actor side ----------------------------------------------------------

    def insert(self, rollout: Rollout,
               priorities: Optional[np.ndarray] = None) -> List[int]:
        if self._arrays is None:
            self._allocate(rollout)
        host = {k: np.asarray(v) for k, v in rollout.items()}
        b = host["action"].shape[1]
        # Optimistic default: fresh rollouts enter at the current max
        # priority so elite sampling visits them at least once before the
        # learner has scored them (the standard PER initialisation).
        default_prio = float(self._prio[self._live].max()) \
            if self._live.any() else 1.0
        ids: List[int] = []
        for i in range(b):
            if not self._free:
                self._evict()
            slot = self._free.pop()
            try:
                for k, arr in self._arrays.items():
                    arr[slot][...] = host[k][:, i]
                self._feat[slot] = _obs_feature(host["obs"][:, i])
            except Exception:
                # Never leak the slot if a malformed rollout dies mid-write.
                self._free.append(slot)
                raise
            self._live[slot] = True
            self._prio[slot] = (default_prio if priorities is None
                                else float(priorities[i]))
            self._seq[slot] = self._next_seq
            self._slot_of_ticket[self._next_seq] = slot
            ids.append(self._next_seq)
            self._next_seq += 1
            self.inserted += 1
        return ids

    # -- learner side ---------------------------------------------------------

    def _choose(self, live: np.ndarray, k: int,
                rng: np.random.Generator,
                query: Optional[Any]) -> np.ndarray:
        """Strategy hook: pick k slot ids from the live set."""
        return rng.choice(live, size=k, replace=len(live) < k)

    def sample(self, k: int, rng: np.random.Generator, *,
               query: Optional[Any] = None) -> Tuple[Rollout, List[int]]:
        live = np.flatnonzero(self._live)
        if len(live) == 0:
            raise ValueError("sample() from an empty replay buffer")
        slots = self._choose(live, k, rng, query)
        batch = {key: np.stack([arr[i] for i in slots], axis=1)
                 for key, arr in self._arrays.items()}
        self.sampled += k
        return batch, [int(self._seq[i]) for i in slots]

    def update_priorities(self, slot_ids, priorities) -> None:
        priorities = np.asarray(priorities, np.float64)
        for i, ticket in enumerate(slot_ids):
            slot = self._slot_of_ticket.get(int(ticket))
            if slot is not None:  # evicted/recycled since sampling: ignore
                self._prio[slot] = priorities[i]

    # -- introspection ---------------------------------------------------------

    def __len__(self) -> int:
        return int(self._live.sum())

    def stats(self) -> Dict[str, float]:
        n = len(self)
        return {
            "occupancy": n / self.capacity,
            "mean_priority": float(self._prio[self._live].mean()) if n else 0.0,
            "inserted": float(self.inserted),
            "evicted": float(self.evicted),
            "sampled": float(self.sampled),
        }

    def clear(self) -> None:
        """Return every slot to the free list (drops contents)."""
        self._live[:] = False
        self._slot_of_ticket.clear()
        self._free = list(range(self.capacity))

    # -- checkpoint/restore (SourceState protocol) -----------------------------

    def state_dict(self) -> Dict[str, Any]:
        """Everything a resumed run needs to sample/evict/score exactly as
        the uninterrupted run would: slot contents, priorities, insertion
        sequence, live ticket map and counters."""
        tickets = np.asarray(sorted(self._slot_of_ticket.items()),
                             np.int64).reshape(-1, 2)
        return {
            "kind": type(self).__name__,
            "capacity": self.capacity,
            "arrays": None if self._arrays is None else
                      {k: v.copy() for k, v in self._arrays.items()},
            "feat": None if self._feat is None else self._feat.copy(),
            "free": np.asarray(self._free, np.int64),
            "live": self._live.copy(),
            "prio": self._prio.copy(),
            "seq": self._seq.copy(),
            "next_seq": self._next_seq,
            "tickets": tickets,
            "inserted": self.inserted,
            "evicted": self.evicted,
            "sampled": self.sampled,
        }

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        if state.get("kind") != type(self).__name__:
            raise ValueError(
                f"checkpoint replay buffer is {state.get('kind')!r}, this "
                f"run built {type(self).__name__} — resume with the same "
                "--replay flags")
        if int(state["capacity"]) != self.capacity:
            raise ValueError(
                f"checkpoint replay capacity {state['capacity']} != "
                f"{self.capacity} — resume with the same --replay-capacity")
        arrays = state["arrays"]
        self._arrays = None if arrays is None else \
            {k: np.asarray(v) for k, v in arrays.items()}
        feat = state["feat"]
        self._feat = None if feat is None else np.asarray(feat, np.float32)
        self._free = [int(i) for i in np.asarray(state["free"])]
        self._live = np.asarray(state["live"], bool)
        self._prio = np.asarray(state["prio"], np.float64)
        self._seq = np.asarray(state["seq"], np.int64)
        self._next_seq = int(state["next_seq"])
        self._slot_of_ticket = {int(t): int(s)
                                for t, s in np.asarray(state["tickets"])}
        self.inserted = int(state["inserted"])
        self.evicted = int(state["evicted"])
        self.sampled = int(state["sampled"])


class UniformReplay(_SlotReplay):
    """FIFO eviction, uniform sampling."""


class EliteReplay(_SlotReplay):
    """Keep what the learner found surprising: sampling ∝ priority^alpha,
    eviction kills the lowest-priority (oldest on ties) rollout."""

    def __init__(self, capacity: int, *, alpha: float = 1.0,
                 min_priority: float = 1e-3):
        super().__init__(capacity)
        self.alpha = alpha
        self.min_priority = min_priority

    def _victim(self) -> int:
        live = np.flatnonzero(self._live)
        # lexsort: lowest priority first, oldest first among equals
        order = np.lexsort((self._seq[live], self._prio[live]))
        return int(live[order[0]])

    def _choose(self, live, k, rng, query):
        p = np.maximum(self._prio[live], self.min_priority) ** self.alpha
        return rng.choice(live, size=k, replace=len(live) < k, p=p / p.sum())

    def update_priorities(self, slot_ids, priorities) -> None:
        priorities = np.maximum(np.asarray(priorities, np.float64),
                                self.min_priority)
        super().update_priorities(slot_ids, priorities)


class AttentiveReplay(_SlotReplay):
    """FIFO eviction; sampling returns the k stored rollouts whose
    mean-observation feature is nearest the query batch's (deterministic
    given buffer contents and query)."""

    needs_query = True

    def _choose(self, live, k, rng, query):
        if query is None:  # no query -> uniform fallback
            return super()._choose(live, k, rng, query)
        q = np.asarray(query, np.float32)
        # query is a full (T+1, B, *obs) fresh batch: average its columns
        qf = np.stack([_obs_feature(q[:, i]) for i in range(q.shape[1])]
                      ).mean(axis=0)
        d = np.linalg.norm(self._feat[live] - qf[None, :], axis=1)
        order = live[np.argsort(d, kind="stable")]
        reps = -(-k // len(order))  # ceil: wrap when k > live
        return np.tile(order, reps)[:k]


class ShardedReplay:
    """Per-device-partitioned replay over a data mesh.

    One inner strategy buffer per mesh device, each holding only that
    device's slice of every inserted batch (capacity is GLOBAL and splits
    evenly). ``insert`` reads per-device shard views of the incoming
    globally-sharded rollout (no global gather); ``sample`` draws k/N
    columns from every partition and re-assembles the global ``(T, k,
    ...)`` batch with ``jax.make_array_from_single_device_arrays`` — a
    metadata-only fan-in, so each device receives exactly its own sampled
    columns and the learner consumes the batch where it lives.

    ``mix`` builds the mixed fresh+replayed batch DEVICE-WISE: device d's
    block is ``concat(fresh_d, replayed_d)`` computed on d (a tiny jitted
    concat), then fanned into the global array. The emitted column layout
    is therefore per-device interleaved — ``[fresh_0 | replay_0 | fresh_1 |
    replay_1 | ...]`` — not globally fresh-first; ``is_replay`` and
    ``emitted_ids`` describe exactly that layout, so the learner's
    per-column priority vector routes back to the right slots.

    Slot ids are ``(device_index, ticket)`` pairs; everything else follows
    the ``ReplayBuffer`` contract.
    """

    def __init__(self, kind: str, capacity: int, mesh, **kwargs):
        from repro.distributed.sharding import rollout_batch_shardings
        self._mesh = mesh
        self._devices = list(mesh.devices.reshape(-1))
        n = len(self._devices)
        if capacity % n != 0:
            raise ValueError(f"replay capacity {capacity} not divisible by "
                             f"mesh size {n}")
        self._parts = [make_buffer(kind, capacity // n, **kwargs)
                       for _ in range(n)]
        self.kind = kind
        self.capacity = capacity
        self.needs_query = bool(getattr(self._parts[0], "needs_query",
                                        False))
        self._shardings = rollout_batch_shardings(mesh)
        self._cat = jax.jit(lambda f, r: jnp.concatenate((f, r), axis=1))

    # -- per-device plumbing ---------------------------------------------------

    def _per_device(self, x, b_local):
        """``x`` as one array per mesh device: zero-copy shard views when
        ``x`` is already laid out over the mesh, host column slices
        otherwise (insert-time fallback for host-resident batches)."""
        if isinstance(x, jax.Array):
            by_dev = {s.device: s.data for s in x.addressable_shards}
            if (all(d in by_dev for d in self._devices)
                    and all(by_dev[d].ndim >= 2
                            and by_dev[d].shape[1] == b_local
                            for d in self._devices)):
                return [by_dev[d] for d in self._devices]
        h = np.asarray(x)
        return [h[:, d * b_local:(d + 1) * b_local]
                for d in range(len(self._devices))]

    def _assemble(self, per_dev: List[Rollout]) -> Rollout:
        n = len(self._devices)
        out = {}
        for key in per_dev[0]:
            shards = [jax.device_put(per_dev[d][key], self._devices[d])
                      for d in range(n)]
            x = shards[0]
            shape = (x.shape[0], x.shape[1] * n) + x.shape[2:]
            out[key] = jax.make_array_from_single_device_arrays(
                shape, self._shardings[x.ndim], shards)
        return out

    # -- ReplayBuffer contract -------------------------------------------------

    def insert(self, rollout: Rollout,
               priorities: Optional[np.ndarray] = None) -> List[Tuple]:
        n = len(self._devices)
        b = rollout["action"].shape[1]
        if b % n != 0:
            raise ValueError(f"batch {b} not divisible by mesh size {n}")
        bl = b // n
        cols = {k: self._per_device(v, bl) for k, v in rollout.items()}
        ids: List[Tuple] = []
        for d in range(n):
            local = {k: np.asarray(v[d]) for k, v in cols.items()}
            pr = None if priorities is None \
                else np.asarray(priorities)[d * bl:(d + 1) * bl]
            ids += [(d, t) for t in self._parts[d].insert(local,
                                                          priorities=pr)]
        return ids

    def sample(self, k: int, rng: np.random.Generator, *,
               query: Optional[Any] = None) -> Tuple[Rollout, List[Tuple]]:
        n = len(self._devices)
        if k % n != 0:
            raise ValueError(
                f"sample size {k} not divisible by mesh size {n} — pick a "
                "--replay-ratio whose replayed column count divides the "
                "mesh")
        kl = k // n
        q = None if query is None else np.asarray(query)
        per_dev, ids = [], []
        for d in range(n):
            q_d = None
            if q is not None:
                bq = q.shape[1] // n
                q_d = q[:, d * bq:(d + 1) * bq]
            local, part_ids = self._parts[d].sample(kl, rng, query=q_d)
            per_dev.append(local)
            ids += [(d, t) for t in part_ids]
        return self._assemble(per_dev), ids

    def mix(self, fresh: Rollout, replayed: Rollout):
        """Device-wise mixed batch: ``concat(fresh_d, replayed_d)`` on each
        device, fanned into one globally-sharded batch + its ``is_replay``
        mask. Schema drift is rejected upstream (``ReplaySource._mix``
        validates fresh/replayed key sets before delegating here)."""
        n = len(self._devices)
        bl = fresh["action"].shape[1] // n
        kl = replayed["action"].shape[1] // n
        per_dev = []
        for d in range(n):
            per_dev.append({})
        for key in fresh:
            f_parts = self._per_device(fresh[key], bl)
            r_parts = self._per_device(replayed[key], kl)
            for d, dev in enumerate(self._devices):
                f_d = f_parts[d] if isinstance(f_parts[d], jax.Array) \
                    else jax.device_put(f_parts[d], dev)
                r_d = r_parts[d] if isinstance(r_parts[d], jax.Array) \
                    else jax.device_put(r_parts[d], dev)
                per_dev[d][key] = self._cat(f_d, r_d)
        batch = self._assemble(per_dev)
        mask = np.tile(np.concatenate([np.zeros(bl, bool),
                                       np.ones(kl, bool)]), n)
        batch["is_replay"] = jnp.asarray(mask)
        return batch

    def emitted_ids(self, fresh_ids: List, replay_ids: List) -> List:
        """Slot ids in the emitted (per-device interleaved) column order —
        the alignment contract for the learner's priority vector."""
        n = len(self._devices)
        bl, kl = len(fresh_ids) // n, len(replay_ids) // n
        out: List = []
        for d in range(n):
            out += list(fresh_ids[d * bl:(d + 1) * bl])
            out += list(replay_ids[d * kl:(d + 1) * kl])
        return out

    def update_priorities(self, slot_ids, priorities) -> None:
        priorities = np.asarray(priorities, np.float64)
        per_part: Dict[int, Tuple[List[int], List[float]]] = {}
        for (d, t), p in zip(slot_ids, priorities):
            ids, prs = per_part.setdefault(int(d), ([], []))
            ids.append(int(t))
            prs.append(float(p))
        for d, (ids, prs) in per_part.items():
            self._parts[d].update_priorities(ids, np.asarray(prs))

    def __len__(self) -> int:
        return sum(len(p) for p in self._parts)

    def stats(self) -> Dict[str, float]:
        n = len(self)
        live_prio = [p._prio[p._live] for p in self._parts if len(p)]
        return {
            "occupancy": n / self.capacity,
            "mean_priority": float(np.concatenate(live_prio).mean())
            if live_prio else 0.0,
            "inserted": float(sum(p.inserted for p in self._parts)),
            "evicted": float(sum(p.evicted for p in self._parts)),
            "sampled": float(sum(p.sampled for p in self._parts)),
        }

    def clear(self) -> None:
        for p in self._parts:
            p.clear()

    def state_dict(self) -> Dict[str, Any]:
        return {"kind": "ShardedReplay", "n": len(self._parts),
                "parts": [p.state_dict() for p in self._parts]}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        if state.get("kind") != "ShardedReplay":
            raise ValueError(
                f"checkpoint replay buffer is {state.get('kind')!r}, this "
                "run built ShardedReplay — resume with the same flags")
        if int(state["n"]) != len(self._parts):
            raise ValueError(
                f"checkpoint replay has {state['n']} partitions, this mesh "
                f"has {len(self._parts)} — resume with the same --mesh-data")
        for p, st in zip(self._parts, state["parts"]):
            p.load_state_dict(st)


_KINDS = {"uniform": UniformReplay, "elite": EliteReplay,
          "attentive": AttentiveReplay}


def make_buffer(kind: str, capacity: int, **kwargs) -> ReplayBuffer:
    """Factory behind the ``--replay {uniform,elite,attentive}`` flag."""
    try:
        cls = _KINDS[kind]
    except KeyError:
        raise ValueError(f"unknown replay kind {kind!r}; "
                         f"choose from {sorted(_KINDS)}") from None
    return cls(capacity, **kwargs)
