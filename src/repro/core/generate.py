"""Autoregressive decoding on a unified per-slot session (the actor-side
inference path for LLM-policy IMPALA, and the serving loop).

There is exactly ONE decode loop in the codebase. ``_session_prefill`` /
``_session_step`` are pure functions over a *session state* pytree with one
row per slot:

    {"cache":  decode cache, leaves (G, B, cap, ...)  (batch axis 1)
     "pos":    (B,) int32  position of the next token to decode
     "last":   (B,) int32  last sampled token (fed on the next step)
     "keys":   (B, 2) uint32  per-slot PRNG keys, split sequentially
     "temp":   (B,) float32  per-slot sampling temperature
     "active": (B,) bool   slots currently decoding}

``generate`` (fixed-batch rollouts: every slot admitted together, no
eviction) and ``DecodeSession`` (continuous batching: admission/eviction
every step via ``prefill_into``/``step``/``evict``) both drive the same
compiled step, shared through a module-level cache keyed by
(cfg, mesh, rules) — a Server, a GeneratorSource and a benchmark arm with
the same config reuse one compile.

Inactive slots still compute (lockstep batch) but their pos/last/keys are
frozen and admission rewrites the whole cache row, so a slot's token
stream is fully determined by its own (prompt, key, temperature) — the
single-request continuous server is bitwise-identical to ``generate``.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batcher import bucket_size
from repro.models import model as model_lib


def _sample_row(key, logits, temp):
    """Sample one token for one slot. logits (V,) fp32."""
    logits = logits / temp
    tok = jax.random.categorical(key, logits)
    lp = jax.nn.log_softmax(logits)
    chosen = lp[tok]
    ent = -jnp.sum(jnp.exp(lp) * lp)
    return tok.astype(jnp.int32), chosen, ent


def _split_rows(keys):
    """(B,2) -> (carry (B,2), use (B,2)): per-slot sequential key split."""
    split = jax.vmap(jax.random.split)(keys)
    return split[:, 0], split[:, 1]


def _out(tok, lp, ent, baseline):
    return {"token": tok, "logprob": lp, "entropy": ent,
            "baseline": (baseline[:, 0] if baseline is not None
                         else jnp.zeros_like(lp))}


def _session_prefill(params, prompt, keys, temp, *, cfg, cache_seq_len,
                     last_index=None, vision=None):
    """Prefill every row and sample its first token.

    prompt (B, P) int32 (may be right-padded; ``last_index`` = index of the
    true last token — scalar shared by every row, or a (B,) array of
    per-row lengths-1, default P-1). Returns (state, out) where ``out``
    holds the FIRST sampled token per row, aligned with
    ``_session_step``'s.
    """
    b, p = prompt.shape
    hidden, _, cache = model_lib.prefill(params, prompt, cfg=cfg,
                                         vision=vision,
                                         cache_seq_len=cache_seq_len)
    if last_index is None:
        h_last = hidden[:, -1:]
        pos0 = jnp.full((b,), p, jnp.int32)
    else:
        li = jnp.asarray(last_index)
        if li.ndim == 0:            # one shared true length
            h_last = jax.lax.dynamic_slice_in_dim(hidden, li, 1, axis=1)
        else:                       # per-row true lengths (batched admit)
            h_last = jnp.take_along_axis(hidden, li[:, None, None], axis=1)
        pos0 = jnp.full((b,), 0, jnp.int32) + (li + 1).astype(jnp.int32)
    logits0 = model_lib.logits_from_hidden(params, cfg, h_last)
    base0 = model_lib.baseline_from_hidden(params, cfg, h_last)
    keys, use = _split_rows(keys)
    tok, lp, ent = jax.vmap(_sample_row)(use, logits0[:, 0], temp)
    state = {"cache": cache, "pos": pos0, "last": tok, "keys": keys,
             "temp": temp, "active": jnp.ones((b,), bool)}
    return state, _out(tok, lp, ent, base0)


def _session_step(params, state, *, cfg):
    """Advance every slot one token. Inactive rows still run (lockstep
    batch) but their pos/last/keys are frozen — their cache writes land in
    their own row only, which admission fully overwrites."""
    pos, last, keys = state["pos"], state["last"], state["keys"]
    temp, active = state["temp"], state["active"]
    logits, baseline, cache = model_lib.serve_step(
        params, last[:, None], state["cache"], pos, cfg=cfg, unroll=True)
    new_keys, use = _split_rows(keys)
    tok, lp, ent = jax.vmap(_sample_row)(use, logits[:, 0], temp)
    new_state = {
        "cache": cache,
        "pos": jnp.where(active, pos + 1, pos),
        "last": jnp.where(active, tok, last),
        "keys": jnp.where(active[:, None], new_keys, keys),
        "temp": temp,
        "active": active,
    }
    return new_state, _out(tok, lp, ent, baseline)


# ---------------------------------------------------------------------------
# compiled-session cache: one set of jitted fns per (cfg, mesh, rules)
# ---------------------------------------------------------------------------

_FNS_CACHE: Dict[tuple, "_SessionFns"] = {}


def _freeze_rules(rules):
    return tuple(sorted(rules.items())) if isinstance(rules, dict) else rules


class _SessionFns:
    """Jitted prefill/step/admit/evict for one (cfg, mesh, rules)."""

    def __init__(self, cfg, mesh, rules):
        self.cfg, self.mesh, self.rules = cfg, mesh, rules

        def _ctx():
            from repro.distributed import sharding as shd
            if mesh is None:
                import contextlib
                return contextlib.nullcontext()
            return shd.use_rules(mesh, rules)

        def _constrain_cache(cache, batch, seq_len):
            if mesh is None:
                return cache
            from repro.launch import specs as specs_lib
            shardings = jax.tree.map(
                lambda s: s.sharding,
                specs_lib.cache_specs(cfg, mesh, batch, seq_len))
            return jax.tree.map(jax.lax.with_sharding_constraint, cache,
                                shardings)

        def prefill(params, prompt, keys, temp, cache_seq_len):
            with _ctx():
                state, out = _session_prefill(params, prompt, keys, temp,
                                              cfg=cfg,
                                              cache_seq_len=cache_seq_len)
                state["cache"] = _constrain_cache(
                    state["cache"], prompt.shape[0], cache_seq_len)
            return state, out

        def step(params, state):
            with _ctx():
                return _session_step(params, state, cfg=cfg)

        def admit(params, state, prompt, length, slot, key, temp,
                  cache_seq_len):
            """Prefill ONE request (prompt (1, Pb), true length ``length``)
            and write it into batch row ``slot``: full cache-row overwrite
            plus pos/last/key/temp/active — nothing of the previous tenant
            survives (no KV-slot leaks across requests)."""
            with _ctx():
                row, out = _session_prefill(
                    params, prompt, key[None], temp[None], cfg=cfg,
                    cache_seq_len=cache_seq_len, last_index=length - 1)
            new_cache = jax.tree.map(
                lambda full, r: jax.lax.dynamic_update_slice_in_dim(
                    full, r.astype(full.dtype), slot, axis=1),
                state["cache"], row["cache"])
            new_state = {
                "cache": new_cache,
                "pos": state["pos"].at[slot].set(length),
                "last": state["last"].at[slot].set(row["last"][0]),
                "keys": state["keys"].at[slot].set(row["keys"][0]),
                "temp": state["temp"].at[slot].set(temp),
                "active": state["active"].at[slot].set(True),
            }
            return new_state, out

        def admit_many(params, state, prompts, lengths, slots, keys,
                       temps, cache_seq_len):
            """Prefill N requests in ONE dispatch (prompts (N, Pb) padded
            to a shared bucket, true lengths (N,)) and scatter them into
            batch rows ``slots`` ((N,) int32, no duplicates): the same
            full-row overwrite as ``admit``, vectorized."""
            with _ctx():
                rows, out = _session_prefill(
                    params, prompts, keys, temps, cfg=cfg,
                    cache_seq_len=cache_seq_len, last_index=lengths - 1)
            new_cache = jax.tree.map(
                lambda full, r: full.at[:, slots].set(
                    r.astype(full.dtype)),
                state["cache"], rows["cache"])
            new_state = {
                "cache": new_cache,
                "pos": state["pos"].at[slots].set(lengths),
                "last": state["last"].at[slots].set(rows["last"]),
                "keys": state["keys"].at[slots].set(rows["keys"]),
                "temp": state["temp"].at[slots].set(temps),
                "active": state["active"].at[slots].set(True),
            }
            return new_state, out

        def evict(state, slot):
            return dict(state, active=state["active"].at[slot].set(False))

        self.prefill = jax.jit(prefill,
                               static_argnames=("cache_seq_len",))
        self.step = jax.jit(step, donate_argnums=(1,))
        self.admit = jax.jit(admit, static_argnames=("cache_seq_len",),
                             donate_argnums=(1,))
        self.admit_many = jax.jit(admit_many,
                                  static_argnames=("cache_seq_len",),
                                  donate_argnums=(1,))
        self.evict = jax.jit(evict, donate_argnums=(0,))


def session_fns(cfg, mesh=None, rules=None) -> _SessionFns:
    key = (cfg, mesh, _freeze_rules(rules))
    if key not in _FNS_CACHE:
        _FNS_CACHE[key] = _SessionFns(cfg, mesh, rules)
    return _FNS_CACHE[key]


def prefill_len(cfg, p: int, max_len: int) -> int:
    """Admission prefill length: bucket-laddered (bounded compile count)
    where right-padding is provably inert, exact otherwise.

    Right-padding is safe only when every padded cache slot is overwritten
    before it becomes attendable: true for full causal attention (decode
    writes slot ``pos`` before attending) and for ring buffers while the
    bucket stays within the window cap. Recurrent mixers (mamba/xlstm)
    carry a scanned state polluted by any suffix -> exact length.
    """
    if p >= max_len:
        return max_len
    if cfg.is_recurrent:
        return p
    pb = bucket_size(p)
    windowed = any(m in ("local_attn", "swa_attn")
                   for m, _ in cfg.block_pattern)
    if windowed and pb > cfg.sliding_window:
        return p
    return min(pb, max_len)


# ---------------------------------------------------------------------------
# DecodeSession: slot-indexed continuous-batching decode state
# ---------------------------------------------------------------------------

class DecodeSession:
    """Slot-indexed decode state with per-step admission/eviction.

    Owns a ``max_batch``-row decode cache (capacity ``max_len`` tokens per
    slot; on a mesh the layout is pinned to ``launch.specs.cache_specs``)
    plus per-slot position/token/RNG/temperature state. The serving loop
    (``launch.serve.Server``) and the RL actor source
    (``core.sources.GeneratorSource``) both drive this API:

      prefill_into(slot, prompt, key=...) -> first-token dict for the slot
      prefill_many(slots, prompts, ...)   -> batched admit: one dispatch
                                             per shared prefill bucket
      step()                              -> per-slot dict for one token
      evict(slot)                         -> frees the slot

    All device work goes through the shared compiled session fns, so many
    sessions with one config pay one compile.
    """

    def __init__(self, params, cfg, *, max_batch: int, max_len: int,
                 mesh=None, rules=None):
        if cfg.vision_seq:
            raise ValueError("DecodeSession serves text-only configs")
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.mesh = mesh
        self._params = params
        self._fns = session_fns(cfg, mesh, rules)
        cache = model_lib.cache_init(cfg, max_batch, max_len)
        if mesh is not None:
            from repro.launch import specs as specs_lib
            shardings = jax.tree.map(
                lambda s: s.sharding,
                specs_lib.cache_specs(cfg, mesh, max_batch, max_len))
            cache = jax.tree.map(jax.device_put, cache, shardings)
        self._state = {
            "cache": cache,
            "pos": jnp.zeros((max_batch,), jnp.int32),
            "last": jnp.zeros((max_batch,), jnp.int32),
            "keys": jnp.zeros((max_batch, 2), jnp.uint32),
            "temp": jnp.ones((max_batch,), jnp.float32),
            "active": jnp.zeros((max_batch,), bool),
        }
        self._active = np.zeros(max_batch, bool)   # host mirror

    @property
    def params(self):
        return self._params

    @params.setter
    def params(self, params) -> None:
        """Swap the served params (e.g. the RL actor following the learner).
        Safe between calls: the compiled fns take params as an argument."""
        self._params = params

    # -- slot bookkeeping ---------------------------------------------------

    @property
    def active(self) -> np.ndarray:
        return self._active.copy()

    @property
    def num_active(self) -> int:
        return int(self._active.sum())

    def free_slot(self) -> Optional[int]:
        free = np.flatnonzero(~self._active)
        return int(free[0]) if free.size else None

    # -- session API --------------------------------------------------------

    def prefill_into(self, slot: int, prompt, *, key,
                     temperature: float = 1.0) -> Dict[str, np.ndarray]:
        """Admit a request into ``slot``. prompt: (P,) int32, P <= max_len-1.
        Returns the first sampled token's {token, logprob, entropy,
        baseline} (scalars, host)."""
        if self._active[slot]:
            raise ValueError(f"slot {slot} is occupied (evict first)")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        p = prompt.shape[0]
        if not 0 < p < self.max_len:
            raise ValueError(f"prompt length {p} not in [1, {self.max_len})")
        pb = prefill_len(self.cfg, p, self.max_len)
        padded = np.zeros((1, pb), np.int32)
        padded[0, :p] = prompt
        self._state, out = self._fns.admit(
            self._params, self._state, jnp.asarray(padded),
            jnp.int32(p), jnp.int32(slot), jnp.asarray(key),
            jnp.float32(temperature), cache_seq_len=self.max_len)
        self._active[slot] = True
        return {k: np.asarray(v)[0] for k, v in out.items()}

    def prefill_many(self, slots, prompts, *, keys,
                     temperature=1.0) -> list:
        """Admit N requests batched: ONE compiled dispatch per shared
        prefill bucket (one total when every prompt pads to the same
        bucket — e.g. the GeneratorSource's single-token episode resets)
        instead of one per slot.

        slots: N slot indices (unique, all free). prompts: N 1-D int32
        prompt arrays (ragged ok). keys: N PRNG keys. temperature: scalar
        or N floats. Returns a list of N per-slot first-token dicts, in
        ``slots`` order — each identical to what ``prefill_into`` returns
        for that (prompt, key, temperature).
        """
        slots = [int(s) for s in slots]
        n = len(slots)
        if len(set(slots)) != n:
            raise ValueError(f"duplicate slots in batched admit: {slots}")
        occupied = [s for s in slots if self._active[s]]
        if occupied:
            raise ValueError(f"slots {occupied} are occupied (evict first)")
        prompts = [np.asarray(p, np.int32).reshape(-1) for p in prompts]
        if len(prompts) != n:
            raise ValueError(f"{n} slots but {len(prompts)} prompts")
        for p in prompts:
            if not 0 < p.shape[0] < self.max_len:
                raise ValueError(f"prompt length {p.shape[0]} not in "
                                 f"[1, {self.max_len})")
        keys = [np.asarray(k, np.uint32).reshape(2) for k in keys]
        temps = np.broadcast_to(
            np.asarray(temperature, np.float32), (n,))

        # group by prefill bucket: each group is one compiled dispatch
        groups: Dict[int, list] = {}
        for i, p in enumerate(prompts):
            pb = prefill_len(self.cfg, p.shape[0], self.max_len)
            groups.setdefault(pb, []).append(i)

        results: list = [None] * n
        for pb, idxs in groups.items():
            g = len(idxs)
            padded = np.zeros((g, pb), np.int32)
            lengths = np.empty((g,), np.int32)
            for row, i in enumerate(idxs):
                p = prompts[i]
                padded[row, :p.shape[0]] = p
                lengths[row] = p.shape[0]
            self._state, out = self._fns.admit_many(
                self._params, self._state, jnp.asarray(padded),
                jnp.asarray(lengths),
                jnp.asarray([slots[i] for i in idxs], jnp.int32),
                jnp.asarray(np.stack([keys[i] for i in idxs])),
                jnp.asarray(temps[idxs]), cache_seq_len=self.max_len)
            host = {k: np.asarray(v) for k, v in out.items()}
            for row, i in enumerate(idxs):
                self._active[slots[i]] = True
                results[i] = {k: v[row] for k, v in host.items()}
        return results

    def step(self) -> Dict[str, np.ndarray]:
        """Advance every active slot one token. Returns per-slot arrays
        (B,); entries for inactive slots are garbage — gate on .active."""
        self._state, out = self._fns.step(self._params, self._state)
        return {k: np.asarray(v) for k, v in out.items()}

    def evict(self, slot: int) -> None:
        self._state = self._fns.evict(self._state, jnp.int32(slot))
        self._active[slot] = False


# ---------------------------------------------------------------------------
# fixed-batch rollouts (IMPALA actors, tests)
# ---------------------------------------------------------------------------

def generate(params, prompt, key, *, cfg, num_steps: int,
             temperature: float = 1.0, vision=None, mesh=None, rules=None):
    """prompt: (B, P) int32. Samples ``num_steps`` tokens for every row
    through the SAME compiled session step the continuous server runs —
    a single-request server trace is bitwise-identical to this function.
    Returns dict:
      tokens    (B, P + num_steps)
      logprob   (B, num_steps)  behavior log-prob of each sampled token
      entropy   (B, num_steps)  policy entropy at each step
      baseline  (B, num_steps)  value estimates V(s_t)
    """
    b, p = prompt.shape
    fns = session_fns(cfg, mesh, rules)
    keys = jax.random.split(key, b)
    temp = jnp.full((b,), temperature, jnp.float32)
    if vision is not None:
        # VLM rollouts keep the one-shot jitted path (no serving analogue).
        return _generate_vision(params, prompt, keys, temp, cfg=cfg,
                                num_steps=num_steps, vision=vision)
    state, out0 = fns.prefill(params, jnp.asarray(prompt, jnp.int32), keys,
                              temp, cache_seq_len=p + num_steps)
    outs = [out0]
    for _ in range(num_steps - 1):
        state, out = fns.step(params, state)
        outs.append(out)
    stackcat = {k: jnp.stack([o[k] for o in outs], axis=1) for k in outs[0]}
    tokens = jnp.concatenate([jnp.asarray(prompt, jnp.int32),
                              stackcat["token"]], axis=1)
    return {
        "tokens": tokens,
        "logprob": stackcat["logprob"],
        "entropy": stackcat["entropy"],
        "baseline": stackcat["baseline"],
    }


@functools.partial(jax.jit, static_argnames=("cfg", "num_steps"))
def _generate_vision(params, prompt, keys, temp, *, cfg, num_steps,
                     vision):
    """One-shot scan rollout for VLM prompts (vision feeds prefill only)."""
    b, p = prompt.shape
    state, out0 = _session_prefill(params, prompt, keys, temp, cfg=cfg,
                                   cache_seq_len=p + num_steps,
                                   vision=vision)

    def body(state, _):
        return _session_step(params, state, cfg=cfg)

    state, traj = jax.lax.scan(body, state, None, length=num_steps - 1)
    full = {k: jnp.concatenate([out0[k][:, None], jnp.swapaxes(v, 0, 1)],
                               axis=1) for k, v in traj.items()}
    tokens = jnp.concatenate([prompt, full["token"]], axis=1)
    return {"tokens": tokens, "logprob": full["logprob"],
            "entropy": full["entropy"], "baseline": full["baseline"]}
