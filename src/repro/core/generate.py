"""Autoregressive generation with the decode cache (the actor-side
inference path for LLM-policy IMPALA, and the serving loop).

``generate`` runs prefill over the prompt then a compiled ``lax.scan`` of
single-token decode steps, sampling from the policy and recording the
behavior log-prob of every sampled token — exactly the data V-trace needs
from the behavior policy (DESIGN.md §2).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import model as model_lib


@functools.partial(jax.jit, static_argnames=("cfg", "num_steps",
                                             "temperature", "attn_impl"))
def generate(params, prompt, key, *, cfg, num_steps: int,
             temperature: float = 1.0, vision=None, attn_impl=None):
    """prompt: (B, P) int32. attn_impl: attention impl for BOTH prefill
    and decode (None -> cfg.attn_impl; 'kernel' = Pallas flash kernel for
    the prefill, Pallas decode-attention kernel per step). Returns dict:
      tokens    (B, P + num_steps)
      logprob   (B, num_steps)  behavior log-prob of each sampled token
      entropy   (B, num_steps)  policy entropy at each step
      baseline  (B, num_steps)  value estimates V(s_t)
    """
    b, p = prompt.shape
    total = p + num_steps
    hidden, _, cache = model_lib.prefill(params, prompt, cfg=cfg,
                                         vision=vision, impl=attn_impl,
                                         cache_seq_len=total)
    logits0 = model_lib.logits_from_hidden(params, cfg, hidden[:, -1:])
    base0 = model_lib.baseline_from_hidden(params, cfg, hidden[:, -1:])

    def sample(key, logits):
        logits = logits / temperature
        tok = jax.random.categorical(key, logits)
        lp = jax.nn.log_softmax(logits, axis=-1)
        chosen = jnp.take_along_axis(lp, tok[..., None], axis=-1)[..., 0]
        ent = -jnp.sum(jnp.exp(lp) * lp, axis=-1)
        return tok.astype(jnp.int32), chosen, ent

    key, k0 = jax.random.split(key)
    tok, lp, ent = sample(k0, logits0[:, 0])

    def step(carry, key):
        cache, tok, lp, ent, base, pos = carry
        logits, baseline, cache = model_lib.serve_step(
            params, tok[:, None], cache, pos, cfg=cfg, impl=attn_impl)
        ntok, nlp, nent = sample(key, logits[:, 0])
        out = {"token": tok, "logprob": lp, "entropy": ent,
               "baseline": base}
        return (cache, ntok, nlp, nent, baseline[:, 0], pos + 1), out

    keys = jax.random.split(key, num_steps)
    carry = (cache, tok, lp, ent,
             base0[:, 0] if base0 is not None else jnp.zeros((b,)),
             jnp.asarray(p, jnp.int32))
    _, traj = jax.lax.scan(step, carry, keys)

    tokens = jnp.concatenate([prompt, traj["token"].T], axis=1)
    return {
        "tokens": tokens,
        "logprob": traj["logprob"].T,
        "entropy": traj["entropy"].T,
        "baseline": traj["baseline"].T,
    }
