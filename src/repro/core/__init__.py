"""IMPALA core: V-trace, losses, rollouts, queueing, learner, and the
unified actor/learner runtime (the paper's primary contribution)."""
from repro.core import (vtrace, losses, rollout, batcher, actor_pool,  # noqa: F401
                        generate, learner, sources, runtime)
