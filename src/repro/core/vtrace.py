"""V-trace off-policy correction (IMPALA, Espeholt et al. 2018, §4.1).

Faithful to DeepMind's scalable_agent/vtrace.py semantics:

  rho_t  = min(rho_clip, pi(a_t|s_t) / mu(a_t|s_t))
  c_t    = min(c_clip,  rho_t_unclipped)
  delta_t = rho_t (r_t + gamma_t V(s_{t+1}) - V(s_t))
  vs_t   = V(s_t) + delta_t + gamma_t c_t (vs_{t+1} - V(s_{t+1}))
  pg_adv = rho_t (r_t + gamma_t vs_{t+1} - V(s_t))

Everything is time-major (T, B), as in the paper's learner-input dict.
The backward recursion is a reverse ``jax.lax.scan``; a Pallas TPU kernel
of the same recursion (blocked over batch lanes) lives in
``repro.kernels.vtrace`` and is validated against this implementation.

All outputs are ``stop_gradient``-ed: V-trace targets are treated as fixed
regression targets, exactly as in the reference implementation.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class VTraceReturns(NamedTuple):
    vs: jnp.ndarray              # (T, B) value targets
    pg_advantages: jnp.ndarray   # (T, B) policy-gradient advantages


def vtrace_from_importance_weights(
        log_rhos, discounts, rewards, values, bootstrap_value,
        *, clip_rho_threshold=1.0, clip_c_threshold=1.0,
        clip_pg_rho_threshold=1.0):
    """log_rhos/discounts/rewards/values: (T, B); bootstrap_value: (B,)."""
    log_rhos = log_rhos.astype(jnp.float32)
    discounts = discounts.astype(jnp.float32)
    rewards = rewards.astype(jnp.float32)
    values = values.astype(jnp.float32)
    bootstrap_value = bootstrap_value.astype(jnp.float32)

    rhos = jnp.exp(log_rhos)
    clipped_rhos = jnp.minimum(clip_rho_threshold, rhos) \
        if clip_rho_threshold is not None else rhos
    cs = jnp.minimum(clip_c_threshold, rhos) \
        if clip_c_threshold is not None else rhos

    values_tp1 = jnp.concatenate(
        [values[1:], bootstrap_value[None]], axis=0)
    deltas = clipped_rhos * (rewards + discounts * values_tp1 - values)

    def body(acc, xs):
        delta_t, discount_t, c_t = xs
        acc = delta_t + discount_t * c_t * acc
        return acc, acc

    _, acc = jax.lax.scan(body, jnp.zeros_like(bootstrap_value),
                          (deltas, discounts, cs), reverse=True)
    vs = values + acc

    vs_tp1 = jnp.concatenate([vs[1:], bootstrap_value[None]], axis=0)
    pg_rhos = jnp.minimum(clip_pg_rho_threshold, rhos) \
        if clip_pg_rho_threshold is not None else rhos
    pg_advantages = pg_rhos * (rewards + discounts * vs_tp1 - values)

    return VTraceReturns(jax.lax.stop_gradient(vs),
                         jax.lax.stop_gradient(pg_advantages))


def vtrace_from_logits(behavior_logits, target_logits, actions, discounts,
                       rewards, values, bootstrap_value, **clip_kwargs):
    """Paper-faithful entry point: full behavior/target logits (T, B, A).

    This is the exact TorchBeast learner-input contract for small action
    spaces (Atari: A=18); LLM-vocab action spaces use
    ``vtrace_from_logprobs`` with stored chosen-action log-probs instead
    (DESIGN.md §2/§8).
    """
    behavior_lp = _action_log_probs(behavior_logits, actions)
    target_lp = _action_log_probs(target_logits, actions)
    return vtrace_from_importance_weights(
        target_lp - behavior_lp, discounts, rewards, values,
        bootstrap_value, **clip_kwargs)


def vtrace_from_logprobs(behavior_logprobs, target_logprobs, discounts,
                         rewards, values, bootstrap_value, **clip_kwargs):
    """LLM-scale entry point: (T, B) chosen-action log-probs."""
    return vtrace_from_importance_weights(
        target_logprobs - behavior_logprobs, discounts, rewards, values,
        bootstrap_value, **clip_kwargs)


def _action_log_probs(logits, actions):
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.take_along_axis(lp, actions[..., None], axis=-1)[..., 0]
