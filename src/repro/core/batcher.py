"""Host-side queueing/batching — the Python reimplementation of PolyBeast's
C++ extension module (batcher.cc semantics, DESIGN.md §1).

``DynamicBatcher``: actor threads call ``compute(inputs)`` and block; a
consumer thread repeatedly calls ``get_batch()`` which gathers up to
``max_batch_size`` pending requests (waiting at most ``timeout_ms`` after the
first arrival), stacks them along ``batch_dim``, and later scatters the
consumer's reply back to each waiting actor. This is the paper's *inference
queue* that keeps accelerator evaluations batched.

``BatchingQueue``: producers ``put`` single rollouts; the consumer iterates
fixed-size stacked batches — the paper's *learner queue*.

Batch sizes are quantised to a bucket ladder (pad-to-bucket) so the compiled
fixed-shape TPU step doesn't recompile per batch size (DESIGN.md §8.4).
"""

from __future__ import annotations

import threading
from typing import Any, List, Optional, Sequence

import jax
import numpy as np


class Closed(Exception):
    """Raised by blocked calls when the queue/batcher is closed."""


def stack_trees(trees: Sequence[Any], axis: int = 0):
    return jax.tree.map(lambda *xs: np.stack(xs, axis=axis), *trees)


def unstack_tree(tree, n: int, axis: int = 0):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    split = [np.split(np.asarray(leaf), n, axis=axis) for leaf in leaves]
    return [jax.tree_util.tree_unflatten(
        treedef, [np.squeeze(s[i], axis=axis) for s in split])
        for i in range(n)]


def bucket_size(n: int, ladder=(1, 2, 4, 8, 16, 32, 64, 128, 256)) -> int:
    for b in ladder:
        if n <= b:
            return b
    return n


class _Pending:
    __slots__ = ("inputs", "event", "output")

    def __init__(self, inputs):
        self.inputs = inputs
        self.event = threading.Event()
        self.output = None


class DynamicBatcher:
    def __init__(self, max_batch_size: int = 32, timeout_ms: float = 10.0,
                 batch_dim: int = 0, pad_to_bucket: bool = True):
        self.max_batch_size = max_batch_size
        self.timeout_s = timeout_ms / 1000.0
        self.batch_dim = batch_dim
        self.pad_to_bucket = pad_to_bucket
        self._pending: List[_Pending] = []
        self._cond = threading.Condition()
        self._closed = False

    def close(self):
        # Snapshot-and-clear under the lock: compute() checks _closed under
        # the same lock, so no request can slip in after the snapshot, and
        # a concurrent get_batch() can't pop entries we are about to wake.
        with self._cond:
            self._closed = True
            pending, self._pending = self._pending, []
            self._cond.notify_all()
        for p in pending:
            p.event.set()  # output stays None -> compute() raises Closed

    def compute(self, inputs):
        """Called by actor threads; blocks until the consumer responds."""
        p = _Pending(inputs)
        with self._cond:
            if self._closed:
                raise Closed
            self._pending.append(p)
            self._cond.notify_all()
        p.event.wait()
        if p.output is None:
            raise Closed
        return p.output

    def get_batch(self, timeout: Optional[float] = None):
        """Called by the consumer. Returns (batched_inputs, respond, size) or
        None on timeout / raises Closed when closed and drained."""
        with self._cond:
            while not self._pending:
                if self._closed:
                    raise Closed
                if not self._cond.wait(timeout=timeout):
                    return None
            # first request arrived; give stragglers timeout_s to join
            if self.timeout_s > 0 and len(self._pending) < self.max_batch_size:
                self._cond.wait_for(
                    lambda: len(self._pending) >= self.max_batch_size
                    or self._closed,
                    timeout=self.timeout_s)
            if not self._pending:  # close() snapshotted it mid-wait
                raise Closed
            batch = self._pending[:self.max_batch_size]
            self._pending = self._pending[self.max_batch_size:]

        n = len(batch)
        stacked = stack_trees([p.inputs for p in batch], self.batch_dim)
        if self.pad_to_bucket:
            target = bucket_size(n)
            if target > n:
                stacked = jax.tree.map(
                    lambda x: np.concatenate(
                        [x] + [x[-1:]] * (target - n), axis=self.batch_dim),
                    stacked)

        def respond(outputs):
            parts = unstack_tree(outputs, _leading_dim(outputs,
                                                       self.batch_dim),
                                 self.batch_dim)
            for p, out in zip(batch, parts[:n]):
                p.output = out
                p.event.set()

        return stacked, respond, n


def _leading_dim(tree, axis):
    leaf = jax.tree_util.tree_leaves(tree)[0]
    return np.asarray(leaf).shape[axis]


class BatchingQueue:
    """Producers put single items; the consumer iterates stacked batches of
    exactly ``batch_size`` along ``batch_dim`` (the learner queue)."""

    def __init__(self, batch_size: int, batch_dim: int = 1,
                 max_items: int = 128):
        self.batch_size = batch_size
        self.batch_dim = batch_dim
        self.max_items = max_items
        self._items: List[Any] = []
        self._cond = threading.Condition()
        self._closed = False

    def put(self, item):
        with self._cond:
            while len(self._items) >= self.max_items and not self._closed:
                self._cond.wait()
            if self._closed:
                raise Closed
            self._items.append(item)
            self._cond.notify_all()

    def get(self, timeout: Optional[float] = None):
        with self._cond:
            self._cond.wait_for(
                lambda: len(self._items) >= self.batch_size or self._closed,
                timeout=timeout)
            if len(self._items) >= self.batch_size:
                items = self._items[:self.batch_size]
                self._items = self._items[self.batch_size:]
                self._cond.notify_all()
            elif self._closed:
                raise Closed
            else:
                return None  # timeout
        return stack_trees(items, self.batch_dim)

    def close(self):
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def __iter__(self):
        while True:
            try:
                batch = self.get()
            except Closed:
                return
            if batch is not None:
                yield batch

    def size(self):
        with self._cond:
            return len(self._items)
