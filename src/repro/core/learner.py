"""IMPALA learner steps.

``make_train_step``      — paper-faithful agent path (full behavior logits,
                           conv/small-action agents; TorchBeast polybeast.py
                           learner loop body).
``make_lm_train_step``   — LLM-policy path (tokens are actions; chosen-action
                           behavior log-probs; chunked vocab head). This is
                           the program lowered for the ``train_4k`` shape.

Both return pure functions suitable for jax.jit/pjit:
  (params, opt_state, step, batch[, extras]) -> (params, opt_state, metrics)
Gradient synchronisation across the mesh data/pod axes comes from sharding
propagation (grads of replicated params -> all-reduce), the TPU analogue of
TorchBeast's multi-learner-thread hogwild updates (DESIGN.md §1).
"""

from __future__ import annotations

import contextlib
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import losses
from repro.models import model as model_lib
from repro.optim.optimizers import apply_updates


def _make_shard_fns(mesh, rules):
    """(batch constrainer, grad constrainer) for a (mesh, rules) context;
    both identity when no mesh is given (the single-device path compiles
    to the exact same program as before)."""
    if mesh is None:
        return (lambda batch: batch), (lambda grads: grads)
    from repro.distributed import sharding as sharding_lib
    if rules is None:
        rules = sharding_lib.RL_AGENT_RULES
    return (lambda batch: sharding_lib.shard_rollout(batch, mesh, rules),
            lambda grads: sharding_lib.replicate(grads, mesh))


def _make_lm_mesh_fns(mesh, rules):
    """(trace-context factory, batch constrainer) for the LM steps under a
    2-D ("data","model") mesh; both identity when no mesh is given (the
    single-device path compiles to the exact same program as before).

    The context activates the (mesh, rules) thread-local so the model's
    ``constrain()`` calls shard activations over "model"; the batch
    constrainer pins the token batch's leading B dimension to the data
    axes (distributed/sharding.py::shard_lm_batch).
    """
    if mesh is None:
        return contextlib.nullcontext, (lambda batch: batch)
    from repro.distributed import sharding as sharding_lib
    if rules is None:
        rules = sharding_lib.MEGATRON_RULES
    return (lambda: sharding_lib.use_rules(mesh, rules),
            lambda batch: sharding_lib.shard_lm_batch(batch, mesh, rules))


def make_train_step(agent_apply: Callable, opt, train_cfg, *,
                    mesh=None, rules=None, vtrace_impl="scan"):
    """Paper-faithful IMPALA learner step over a rollout batch.

    batch: time-major dict (see core/rollout.py):
      obs (T+1,B,...), action (T,B), behavior_logits (T,B,A),
      reward (T,B), done (T,B) [, is_replay (B,) — ReplaySource batches]

    With an ``is_replay`` mask present, the CLEAR cloning terms
    (losses.clear_auxiliary_loss) are applied to the replayed columns at
    ``train_cfg.clear_policy_cost`` / ``clear_value_cost``, and the
    reported ``reward_per_step`` covers the fresh columns only (replayed
    rewards are not new environment signal).

    mesh/rules: optional data-parallel context (distributed/sharding.py).
    The batch is constrained to shard its B dimension over the mesh data
    axes and the gradients to be replicated — the cross-device all-reduce
    falls out of sharding propagation (module docstring).
    vtrace_impl: 'scan' or 'kernel' (the Pallas V-trace recursion).
    """
    shard_batch, shard_grads = _make_shard_fns(mesh, rules)

    def loss_fn(params, batch):
        out = agent_apply(params, batch["obs"])       # (T+1, B, ...)
        target_logits = out.policy_logits[:-1]
        values = out.baseline[:-1]
        bootstrap = jax.lax.stop_gradient(out.baseline[-1])
        discounts = (~batch["done"]).astype(jnp.float32) * train_cfg.discount
        loss_out = losses.impala_loss_from_logits(
            target_logits, batch["behavior_logits"], batch["action"],
            batch["reward"], discounts, values, bootstrap,
            baseline_cost=train_cfg.baseline_cost,
            entropy_cost=train_cfg.entropy_cost,
            clip_rho=train_cfg.vtrace_rho_clip,
            clip_c=train_cfg.vtrace_c_clip,
            is_replay=batch.get("is_replay"),
            behavior_values=batch.get("behavior_value"),
            clear_policy_cost=train_cfg.clear_policy_cost,
            clear_value_cost=train_cfg.clear_value_cost,
            vtrace_impl=vtrace_impl)
        return loss_out.total, loss_out

    def train_step(params, opt_state, step, batch):
        batch = shard_batch(batch)
        grads, loss_out = jax.grad(loss_fn, has_aux=True)(params, batch)
        grads = shard_grads(grads)
        updates, opt_state = opt.update(grads, opt_state, params, step)
        params = apply_updates(params, updates)
        if "is_replay" in batch:
            fresh = (~batch["is_replay"]).astype(jnp.float32)[None, :]
            reward_per_step = (batch["reward"] * fresh).sum() \
                / jnp.maximum(fresh.sum() * batch["reward"].shape[0], 1.0)
        else:
            reward_per_step = batch["reward"].mean()
        metrics = {
            "loss": loss_out.total,
            "pg_loss": loss_out.pg_loss,
            "baseline_loss": loss_out.baseline_loss,
            "entropy_loss": loss_out.entropy_loss,
            "vs_mean": loss_out.vs_mean,
            "rho_mean": loss_out.rho_mean,
            "reward_per_step": reward_per_step,
            "priority": loss_out.priority,
        }
        if "is_replay" in batch:
            metrics["clear_policy_loss"] = loss_out.clear_policy_loss
            metrics["clear_value_loss"] = loss_out.clear_value_loss
        return params, opt_state, metrics

    return train_step


def make_recurrent_train_step(agent_apply, opt, train_cfg, *,
                              mesh=None, rules=None, vtrace_impl="scan"):
    """IMPALA learner for recurrent agents: re-runs the LSTM over the
    unroll from the stored initial core_state (TorchBeast's learner does
    exactly this), then V-trace as usual. batch adds "core_state".
    mesh/rules/vtrace_impl as in ``make_train_step``."""
    shard_batch, shard_grads = _make_shard_fns(mesh, rules)

    def loss_fn(params, batch):
        def step(core_state, xs):
            obs, pre_done = xs
            out = agent_apply(params, obs, core_state, pre_done)
            return out.core_state, (out.policy_logits, out.baseline)

        # re-run the recurrence over the T+1 observations from the stored
        # initial core_state; pre_done[t] zeroes the state exactly where
        # the actor did (fresh-episode observations)
        _, (logits, baselines) = jax.lax.scan(
            step, batch["core_state"], (batch["obs"], batch["pre_done"]))
        t = batch["action"].shape[0]
        target_logits = logits[:t]
        values = baselines[:t]
        bootstrap = jax.lax.stop_gradient(baselines[t])
        discounts = (~batch["done"]).astype(jnp.float32) * train_cfg.discount
        loss_out = losses.impala_loss_from_logits(
            target_logits, batch["behavior_logits"], batch["action"],
            batch["reward"], discounts, values, bootstrap,
            baseline_cost=train_cfg.baseline_cost,
            entropy_cost=train_cfg.entropy_cost,
            clip_rho=train_cfg.vtrace_rho_clip,
            clip_c=train_cfg.vtrace_c_clip,
            vtrace_impl=vtrace_impl)
        return loss_out.total, loss_out

    def train_step(params, opt_state, step, batch):
        batch = shard_batch(batch)
        grads, loss_out = jax.grad(loss_fn, has_aux=True)(params, batch)
        grads = shard_grads(grads)
        updates, opt_state = opt.update(grads, opt_state, params, step)
        params = apply_updates(params, updates)
        metrics = {"loss": loss_out.total, "pg_loss": loss_out.pg_loss,
                   "entropy_loss": loss_out.entropy_loss,
                   "reward_per_step": batch["reward"].mean()}
        return params, opt_state, metrics

    return train_step


def make_lm_train_step(cfg, opt, train_cfg, loss_chunk=512,
                       grad_constraint=None, vtrace_impl="scan",
                       mesh=None, rules=None):
    """IMPALA learner step for LLM policies (DESIGN.md §2).

    grad_constraint: optional fn(grads)->grads applied right after jax.grad
    — the launcher passes a sharding constraint here (grads pinned to the
    param shardings for the Megatron layout, or a ZeRO-2 constraint so the
    gradient all-reduce becomes a reduce-scatter and the fp32 optimizer
    temporaries stay sharded over the data axes).
    vtrace_impl: 'scan' or 'kernel' (the Pallas V-trace recursion).
    Attention/SSD impls come from ``cfg.attn_impl`` / ``cfg.ssd_impl``,
    resolved once at the CLI boundary via ``configs.base.ImplContext``
    ('kernel' selects the Pallas flash kernel).
    mesh/rules: optional 2-D ("data","model") context
    (distributed/sharding.py; rules default MEGATRON_RULES). The token
    batch is constrained to shard B over the data axes and the model's
    ``constrain()`` calls activate (params/activations over "model"); the
    cross-data-axis gradient all-reduce falls out of sharding propagation,
    exactly as in ``make_train_step``. At mesh (1, 1) the compiled program
    is bit-identical to the unmeshed one (tests/test_mesh2d.py).

    batch (batch-major; transposed internally for V-trace):
      tokens            (B, S+1) int32   obs[0..S]; actions are tokens[1:]
      behavior_logprob  (B, S) float32   mu(a_t|s_t) of the generating policy
      reward            (B, S) float32
      done              (B, S) bool
      [vision]          (B, Sv, d)       VLM patch embeddings (stub)
    """
    mesh_ctx, shard_batch = _make_lm_mesh_fns(mesh, rules)

    def loss_fn(params, batch):
        tokens = batch["tokens"]          # (B, S+1); model sees first S
        vision = batch.get("vision")
        # hidden[t] is the state after consuming token t => predicts t+1.
        # Forward over tokens[:, :-1] keeps S divisible by the chunk sizes.
        hidden, aux, _ = model_lib.forward(params, tokens[:, :-1], cfg=cfg,
                                           vision=vision)
        actions = tokens[:, 1:]
        unembed = model_lib.unembed_matrix(params, cfg)
        logprob, entropy = losses.chunked_logprob_entropy(
            hidden, unembed, actions, chunk=loss_chunk,
            final_softcap=cfg.final_logit_softcap)
        values_all = model_lib.baseline_from_hidden(params, cfg, hidden)
        bootstrap = jnp.zeros((tokens.shape[0],), jnp.float32)

        tm = lambda x: jnp.swapaxes(x, 0, 1)  # noqa: E731  batch->time major
        discounts = (~batch["done"]).astype(jnp.float32) * train_cfg.discount
        loss_out = losses.impala_loss_from_logprobs(
            tm(logprob), tm(entropy), tm(batch["behavior_logprob"]),
            tm(batch["reward"]), tm(discounts), tm(values_all), bootstrap,
            baseline_cost=train_cfg.baseline_cost,
            entropy_cost=train_cfg.entropy_cost,
            clip_rho=train_cfg.vtrace_rho_clip,
            clip_c=train_cfg.vtrace_c_clip,
            vtrace_impl=vtrace_impl)
        lb, zl, _ = aux
        total = loss_out.total + cfg.router_aux_weight * lb \
            + cfg.router_z_weight * zl
        return total, loss_out

    def train_step(params, opt_state, step, batch):
        with mesh_ctx():
            batch = shard_batch(batch)
            grads, loss_out = jax.grad(loss_fn, has_aux=True)(params, batch)
            if grad_constraint is not None:
                grads = grad_constraint(grads)
            updates, opt_state = opt.update(grads, opt_state, params, step)
            params = apply_updates(params, updates)
        metrics = {
            "loss": loss_out.total,
            "pg_loss": loss_out.pg_loss,
            "baseline_loss": loss_out.baseline_loss,
            "entropy_loss": loss_out.entropy_loss,
            "reward_per_step": batch["reward"].mean(),
        }
        return params, opt_state, metrics

    return train_step


def make_lm_pretrain_step(cfg, opt, loss_chunk=512, grad_constraint=None,
                          mesh=None, rules=None):
    """Plain next-token-prediction step (substrate completeness: the data
    pipeline / LM pretraining driver; also the non-RL baseline).
    grad_constraint/mesh/rules as in ``make_lm_train_step`` (impls come
    from ``cfg.attn_impl``/``cfg.ssd_impl``) — ``--mode lm --mesh-data N
    --mesh-model M`` runs through the same 2-D mesh path."""
    mesh_ctx, shard_batch = _make_lm_mesh_fns(mesh, rules)

    def loss_fn(params, batch):
        tokens = batch["tokens"]          # (B, S+1)
        hidden, aux, _ = model_lib.forward(params, tokens[:, :-1], cfg=cfg,
                                           vision=batch.get("vision"))
        unembed = model_lib.unembed_matrix(params, cfg)
        loss = losses.chunked_softmax_xent(
            hidden, unembed, tokens[:, 1:], chunk=loss_chunk,
            final_softcap=cfg.final_logit_softcap)
        lb, zl, _ = aux
        return loss + cfg.router_aux_weight * lb + cfg.router_z_weight * zl, loss

    def train_step(params, opt_state, step, batch):
        with mesh_ctx():
            batch = shard_batch(batch)
            grads, xent = jax.grad(loss_fn, has_aux=True)(params, batch)
            if grad_constraint is not None:
                grads = grad_constraint(grads)
            updates, opt_state = opt.update(grads, opt_state, params, step)
            params = apply_updates(params, updates)
        return params, opt_state, {"loss": xent}

    return train_step
