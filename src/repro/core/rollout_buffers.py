"""MonoBeast's shared rollout-buffer scheme (paper §5.1), faithfully:

* ``num_buffers`` preallocated rollout slots (numpy arrays standing in for
  the paper's shared-memory torch tensors — same recycling semantics),
* a ``free_queue`` and a ``full_queue`` communicating integer indices,
* actors dequeue a free index, fill ``buffers[index]`` in place, enqueue it
  to ``full_queue``;
* the learner dequeues ``batch_size`` indices, stacks them into a batch,
  and returns the indices to ``free_queue``.

This is the zero-copy alternative to core/batcher.py's BatchingQueue (which
stacks fresh arrays per rollout); with ``num_buffers`` bounded it also
provides the paper's implicit back-pressure.
"""

from __future__ import annotations

import queue
from typing import Dict, List, Sequence

import numpy as np


class RolloutBuffers:
    def __init__(self, specs: Dict[str, tuple], num_buffers: int):
        """specs: name -> (shape, dtype) WITHOUT a batch dimension, e.g.
        {"obs": ((T+1, 84, 84, 4), np.float32), "action": ((T,), np.int32)}.
        """
        self.specs = specs
        self.num_buffers = num_buffers
        self.buffers: List[Dict[str, np.ndarray]] = [
            {k: np.empty(shape, dtype) for k, (shape, dtype) in specs.items()}
            for _ in range(num_buffers)
        ]
        self.free_queue: "queue.Queue[int]" = queue.Queue()
        self.full_queue: "queue.Queue[int]" = queue.Queue()
        for i in range(num_buffers):
            self.free_queue.put(i)

    # --- actor side ---------------------------------------------------------

    def acquire(self, timeout=None) -> int:
        """Dequeue a free buffer index (blocks — the paper's back-pressure)."""
        return self.free_queue.get(timeout=timeout)

    def commit(self, index: int) -> None:
        self.full_queue.put(index)

    def write(self, index: int, data: Dict[str, np.ndarray]) -> None:
        """In-place fill of buffers[index] (shared-memory write analogue)."""
        buf = self.buffers[index]
        for k, v in data.items():
            buf[k][...] = v

    # --- learner side --------------------------------------------------------

    def get_batch(self, batch_size: int, timeout=None,
                  batch_dim: int = 1) -> Dict[str, np.ndarray]:
        """Dequeue batch_size indices, stack, recycle the indices.

        The stack COPIES (as MonoBeast's torch.stack onto the GPU does), so
        recycling the indices immediately afterwards is safe — exactly the
        paper's ordering (stack, then put indices back, then learn).

        If the learner dies mid-batch (timeout waiting for the remaining
        indices, or an exception while stacking), every index already
        dequeued is returned to the free list — slots must never leak, or
        the bounded-buffer back-pressure eventually deadlocks the actors.
        """
        idxs: List[int] = []
        try:
            for _ in range(batch_size):
                idxs.append(self.full_queue.get(timeout=timeout))
            batch = {k: np.stack([self.buffers[i][k] for i in idxs],
                                 axis=batch_dim)
                     for k in self.specs}
        finally:
            for i in idxs:
                self.free_queue.put(i)
        return batch

    def qsizes(self):
        return {"free": self.free_queue.qsize(),
                "full": self.full_queue.qsize()}


def rollout_specs(obs_shape: Sequence[int], num_actions: int,
                  unroll_length: int) -> Dict[str, tuple]:
    """The §2 learner-input dict layout, per single rollout (no batch dim)."""
    t = unroll_length
    return {
        "obs": ((t + 1,) + tuple(obs_shape), np.float32),
        "action": ((t,), np.int32),
        "behavior_logits": ((t, num_actions), np.float32),
        "reward": ((t,), np.float32),
        "done": ((t,), np.bool_),
    }
