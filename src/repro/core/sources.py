"""Pluggable rollout sources — the actor side of the IMPALA split, behind
one contract (rlpyt/TorchRL-style modular collectors).

Every source produces *canonical time-major rollouts*: a dict pytree with

  obs              (T+1, B, *obs_shape)   observations (obs[T] bootstraps)
  action           (T, B) int32
  behavior_logits  (T, B, A) float32      — full-logits agents, or
  behavior_logprob (T, B) float32         — chosen-action log-probs (LM path)
  reward           (T, B) float32
  done             (T, B) bool

exactly the learner-input layout of the paper's §2, so the `Runtime`
(core/runtime.py) is indifferent to *how* rollouts are produced:

  ``DeviceSource``    — compiled on-device unroll (core/rollout.py), with
                        optional double-buffered async dispatch: unroll N+1
                        is dispatched with the params of step N-1 before the
                        learner consumes unroll N, so acting and learning
                        overlap at a one-step parameter lag (V-trace corrects
                        the resulting off-policyness — the IMPALA argument).
  ``HostLoopSource``  — MonoBeast/PolyBeast host actor threads feeding the
                        inference queue (DynamicBatcher) and the learner
                        queue (BatchingQueue).
  ``GeneratorSource`` — LLM-policy token-MDP episodes via the decode path
                        (core/generate.py), re-laid-out time-major.
  ``DataSource``      — any iterator of ready batches (LM pretraining).
  ``ReplaySource``    — off-policy replay composition over any of the
                        above: inserts fresh rollouts into a ReplayBuffer
                        (core/replay.py) and emits mixed fresh+replayed
                        batches tagged with an ``is_replay`` column mask.

SourceState: every source is a stateful, checkpointable object.
``state_dict()`` captures everything the rollout stream depends on — env
carries, RNG key streams, dispatch bookkeeping (the double-buffered
in-flight rollout and held behavior params), replay-buffer slots and
priorities — as a plain pytree of dicts/lists/tuples/scalars/arrays;
``load_state_dict()`` restores it into a freshly-constructed source of the
same shape. The Runtime saves it inside every checkpoint
(checkpoint.save ``structured=``) and ``train.py --resume`` restores it, so
a killed-and-resumed run replays the exact batch stream of an
uninterrupted one (bit-identical final params). The one exception is the
host-loop path: Python thread scheduling is not replayable, so
``HostLoopSource`` restarts its actors fresh and only the learner + replay
state resumes exactly.
"""

from __future__ import annotations

import warnings
from typing import Any, Callable, Dict, Iterator, Optional, Protocol, \
    runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np


@runtime_checkable
class RolloutSource(Protocol):
    """The contract the Runtime consumes.

    ``next_batch(params)`` hands the source the learner's *current*
    parameters and returns one rollout batch. Sources are free to act with
    lagged parameters (that is the point of the decoupled architecture);
    the rollout's behavior outputs must describe the policy that actually
    produced it.

    ``state_dict()``/``load_state_dict()`` are the SourceState
    checkpoint/restore protocol (module docstring): sources with no
    resumable state return ``{"kind": ...}`` and ignore loads, but every
    source must answer, so composition (ReplaySource over anything) nests
    checkpoints without special-casing.
    """

    frames_per_batch: int

    def start(self, params) -> None: ...

    def next_batch(self, params) -> Dict[str, Any]: ...

    def stop(self) -> None: ...

    def state_dict(self) -> Dict[str, Any]: ...

    def load_state_dict(self, state: Dict[str, Any]) -> None: ...


def _check_kind(state: Dict[str, Any], obj) -> None:
    """Loud resume-composition guard: a checkpoint written by one source
    shape must not be loaded into another (e.g. --replay elite saved,
    resumed without --replay)."""
    kind = state.get("kind") if hasattr(state, "get") else None
    if kind != type(obj).__name__:
        raise ValueError(
            f"checkpoint source state is {kind!r} but this run built "
            f"{type(obj).__name__} — resume with the same source flags "
            "(--actors/--mesh-data/--replay)")


def check_rollout(rollout: Dict[str, Any], unroll_length: int,
                  batch_size: int) -> None:
    """Assert the canonical time-major contract (used by tests and as the
    executable spec of the layout above)."""
    t, b = unroll_length, batch_size
    assert rollout["obs"].shape[:2] == (t + 1, b), rollout["obs"].shape
    assert rollout["action"].shape == (t, b)
    assert rollout["action"].dtype == jnp.int32
    assert rollout["reward"].shape == (t, b)
    assert rollout["reward"].dtype == jnp.float32
    assert rollout["done"].shape == (t, b)
    assert rollout["done"].dtype == jnp.bool_
    assert ("behavior_logits" in rollout) != ("behavior_logprob" in rollout)
    if "behavior_logits" in rollout:
        assert rollout["behavior_logits"].shape[:2] == (t, b)
        assert rollout["behavior_logits"].dtype == jnp.float32
    else:
        assert rollout["behavior_logprob"].shape == (t, b)
        assert rollout["behavior_logprob"].dtype == jnp.float32


# ---------------------------------------------------------------------------
# Compiled on-device actors


class _CompiledUnrollSource:
    """Shared dispatch cadence for compiled-unroll sources.

    Synchronous (``pipelined=False``): ``next_batch(params)`` dispatches one
    unroll with the given params and returns it — unroll N sees the params
    of step N.

    Double-buffered (``pipelined=True``): one unroll is always in flight.
    ``next_batch(params)`` returns the previously dispatched unroll and
    immediately dispatches the next one, so from step 1 onward the consumed
    rollout was generated with the params of the *previous* learner step
    (parameter lag 1) and the device can execute it while the host is busy
    with the learner step. JAX's async dispatch plus carry donation make
    this a true overlap without threads. With frozen params both modes
    produce bit-identical rollout streams (same key-split sequence).

    ``param_sync_every=k`` refreshes the behavior params only every k-th
    dispatch — the actor-lag knob used by examples/vtrace_ablation.py.

    Subclasses implement ``_sync_behavior(params)`` (how learner params
    become the held behavior params) and ``_unroll_once()`` (advance the
    carry/key state one unroll using ``self._behavior_params``).
    """

    def _init_dispatch(self, *, pipelined: bool, param_sync_every: int):
        self.pipelined = pipelined
        self.param_sync_every = max(1, param_sync_every)
        self._behavior_params = None
        self._dispatches = 0
        self._pending = None

    def _sync_behavior(self, params):
        raise NotImplementedError

    def _unroll_once(self):
        raise NotImplementedError

    def _dispatch(self, params):
        if self._dispatches % self.param_sync_every == 0:
            self._behavior_params = self._sync_behavior(params)
        self._dispatches += 1
        return self._unroll_once()

    def start(self, params) -> None:
        del params  # first dispatch happens lazily in next_batch

    def next_batch(self, params):
        if not self.pipelined:
            return self._dispatch(params)
        if self._pending is None:
            self._pending = self._dispatch(params)
        rollout, self._pending = self._pending, self._dispatch(params)
        return rollout

    def stop(self) -> None:
        """Drop the in-flight rollout AND the dispatch/behavior-param state:
        a stop/start cycle must behave like a fresh source, not resume the
        ``param_sync_every`` cadence with last run's stale parameters."""
        self._pending = None
        self._behavior_params = None
        self._dispatches = 0

    # -- SourceState protocol -------------------------------------------------
    #
    # Captured at a step boundary (periodic/final checkpoints are), this is
    # the COMPLETE dispatch state: carry + key stream (subclass hook), the
    # dispatch counter (param_sync_every cadence), the in-flight
    # double-buffered rollout, and the held behavior params (which may lag
    # the learner params the resume restores). Restoring all of it makes
    # the resumed rollout stream bit-identical to the uninterrupted one.

    def _stream_state(self) -> Dict[str, Any]:
        raise NotImplementedError

    def _load_stream_state(self, state: Dict[str, Any]) -> None:
        raise NotImplementedError

    def _load_rollout(self, rollout):
        raise NotImplementedError

    def _load_behavior(self, behavior_params):
        raise NotImplementedError

    def state_dict(self) -> Dict[str, Any]:
        host = lambda tree: jax.tree.map(np.asarray, tree)  # noqa: E731
        return {
            "kind": type(self).__name__,
            "dispatches": self._dispatches,
            "pending": None if self._pending is None
            else host(self._pending),
            "behavior_params": None if self._behavior_params is None
            else host(self._behavior_params),
            "stream": self._stream_state(),
        }

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        _check_kind(state, self)
        self._dispatches = int(state["dispatches"])
        self._load_stream_state(state["stream"])
        pending = state["pending"]
        self._pending = None if pending is None \
            else self._load_rollout(pending)
        behavior = state["behavior_params"]
        self._behavior_params = None if behavior is None \
            else self._load_behavior(behavior)


def _unflatten_like(template, tree):
    """Rebuild ``tree`` (whose container types degraded to dict/list/tuple
    in the checkpoint) into the pytree STRUCTURE of ``template`` — the
    restore path for env carries that use NamedTuple states."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template),
        [jnp.asarray(x) for x in leaves])


class DeviceSource(_CompiledUnrollSource):
    """Single-device compiled-unroll source (see _CompiledUnrollSource for
    the pipelining/param-sync semantics)."""

    def __init__(self, unroll: Callable, carry, key, *,
                 unroll_length: int, batch_size: int,
                 pipelined: bool = True, param_sync_every: int = 1,
                 donate: Optional[bool] = None):
        if donate is None:  # buffer donation is a no-op (and noisy) on CPU
            donate = jax.default_backend() != "cpu"
        self._unroll = jax.jit(unroll, donate_argnums=(1,) if donate else ())
        self._carry = carry
        self._key = key
        self.unroll_length = unroll_length
        self.batch_size = batch_size
        self.frames_per_batch = unroll_length * batch_size
        self._init_dispatch(pipelined=pipelined,
                            param_sync_every=param_sync_every)

    @classmethod
    def for_env(cls, env, apply_fn, *, unroll_length: int, batch_size: int,
                key, **kwargs) -> "DeviceSource":
        """Build the feed-forward-agent source from an Env + apply_fn."""
        from repro.core import rollout as rollout_lib
        key, k_reset = jax.random.split(key)
        carry = rollout_lib.env_reset_batch(env, k_reset, batch_size)
        unroll = rollout_lib.make_unroll(env, apply_fn, unroll_length)
        return cls(unroll, carry, key, unroll_length=unroll_length,
                   batch_size=batch_size, **kwargs)

    def _sync_behavior(self, params):
        return params

    def _unroll_once(self):
        self._key, k = jax.random.split(self._key)
        self._carry, rollout = self._unroll(self._behavior_params,
                                            self._carry, k)
        return rollout

    def _stream_state(self):
        return {"carry": jax.tree.map(np.asarray, self._carry),
                "key": np.asarray(self._key)}

    def _load_stream_state(self, state):
        self._carry = _unflatten_like(self._carry, state["carry"])
        self._key = jnp.asarray(state["key"])

    def _load_rollout(self, rollout):
        return jax.tree.map(jnp.asarray, rollout)

    def _load_behavior(self, behavior_params):
        return jax.tree.map(jnp.asarray, behavior_params)


# ---------------------------------------------------------------------------
# Data-parallel sharded actors (one stream per mesh data-axis device)


class ShardedDeviceSource(_CompiledUnrollSource):
    """N independent compiled actor streams — one per device of a 1-D
    ("data",) mesh — fanned into ONE globally-sharded rollout batch.

    Each device owns its slice of the global batch: an independent env
    carry, RNG key stream and compiled unroll, all resident on that device.
    ``next_batch`` dispatches every per-device unroll and assembles the
    global (T, B_global, ...) batch with
    ``jax.make_array_from_single_device_arrays`` — a metadata-only
    operation, so there is no host-side concatenation and no cross-device
    traffic: the learner step consumes the batch exactly where it was
    produced, sharded over the mesh data axis.

    Double buffering (``pipelined``) and the actor-lag knob
    (``param_sync_every``) come from _CompiledUnrollSource; at mesh size 1
    the emitted rollout stream is bit-identical to ``DeviceSource``'s
    (same key-split sequence — the mesh-1 parity guarantee of the sharded
    learner).
    """

    def __init__(self, unroll: Callable, carries, keys, mesh, *,
                 unroll_length: int, batch_size: int,
                 pipelined: bool = True, param_sync_every: int = 1,
                 donate: Optional[bool] = None):
        from repro.distributed.sharding import rollout_batch_shardings
        self._mesh = mesh
        self._devices = list(mesh.devices.reshape(-1))
        if len(carries) != len(self._devices):
            raise ValueError(f"{len(carries)} carries for "
                             f"{len(self._devices)} mesh devices")
        if donate is None:
            donate = jax.default_backend() != "cpu"
        self._unroll = jax.jit(unroll, donate_argnums=(1,) if donate else ())
        self._carries = list(carries)
        self._keys = list(keys)
        self.unroll_length = unroll_length
        self.batch_size = batch_size
        self.frames_per_batch = unroll_length * batch_size
        self._init_dispatch(pipelined=pipelined,
                            param_sync_every=param_sync_every)
        self._shardings = rollout_batch_shardings(mesh)

    @classmethod
    def for_env(cls, env, apply_fn, *, unroll_length: int, batch_size: int,
                key, mesh, **kwargs) -> "ShardedDeviceSource":
        """Per-device actor streams for an Env + apply_fn over ``mesh``.

        ``batch_size`` is GLOBAL and must divide by the mesh size; device 0
        reuses the base key stream (mesh-1 bit-parity with
        ``DeviceSource.for_env``), devices d>0 fold ``d`` into it.
        """
        from repro.core import rollout as rollout_lib
        devices = list(mesh.devices.reshape(-1))
        n = len(devices)
        if batch_size % n != 0:
            raise ValueError(f"global batch {batch_size} not divisible by "
                             f"mesh size {n}")
        b_local = batch_size // n
        key, k_reset = jax.random.split(key)
        carries, keys = [], []
        for d, dev in enumerate(devices):
            k_d = key if d == 0 else jax.random.fold_in(key, d)
            kr_d = k_reset if d == 0 else jax.random.fold_in(k_reset, d)
            carries.append(jax.device_put(
                rollout_lib.env_reset_batch(env, kr_d, b_local), dev))
            keys.append(jax.device_put(k_d, dev))
        unroll = rollout_lib.make_unroll(env, apply_fn, unroll_length)
        return cls(unroll, carries, keys, mesh,
                   unroll_length=unroll_length, batch_size=batch_size,
                   **kwargs)

    def _params_on(self, params, dev):
        """A single-device view of ``params`` on ``dev`` — a zero-copy
        shard view when the params are mesh-replicated arrays, a transfer
        only when they live elsewhere."""

        def one(x):
            if isinstance(x, jax.Array):
                for s in x.addressable_shards:
                    if s.device == dev and s.data.shape == x.shape:
                        return s.data
            return jax.device_put(x, dev)

        return jax.tree.map(one, params)

    def _sync_behavior(self, params):
        return [self._params_on(params, dev) for dev in self._devices]

    def _unroll_once(self):
        shards = []
        for i in range(len(self._devices)):
            self._keys[i], k = jax.random.split(self._keys[i])
            self._carries[i], rollout = self._unroll(
                self._behavior_params[i], self._carries[i], k)
            shards.append(rollout)
        return self._assemble(shards)

    def _assemble(self, shards):
        n = len(self._devices)

        def one(*leaves):
            x = leaves[0]
            shape = (x.shape[0], x.shape[1] * n) + x.shape[2:]
            return jax.make_array_from_single_device_arrays(
                shape, self._shardings[x.ndim], list(leaves))

        return jax.tree.map(one, *shards)

    # -- SourceState hooks (per-device stream fan-out) -------------------------

    def _stream_state(self):
        return {"n": len(self._devices),
                "carries": [jax.tree.map(np.asarray, c)
                            for c in self._carries],
                "keys": [np.asarray(k) for k in self._keys]}

    def _load_stream_state(self, state):
        n = len(self._devices)
        if int(state["n"]) != n:
            raise ValueError(
                f"checkpoint source state spans {state['n']} devices, this "
                f"mesh has {n} — resume with the same --mesh-data")
        self._carries = [
            jax.device_put(_unflatten_like(self._carries[d], c), dev)
            for d, (c, dev) in enumerate(zip(state["carries"],
                                             self._devices))]
        self._keys = [jax.device_put(jnp.asarray(k), dev)
                      for k, dev in zip(state["keys"], self._devices)]

    def _load_rollout(self, rollout):
        """Re-shard a host rollout saved from the globally-sharded pending
        batch: slice each leaf's columns back to its owning device and
        re-assemble (metadata-only) — the restored pending batch lives
        exactly where the original did."""
        n = len(self._devices)

        def split(x):
            x = np.asarray(x)
            bl = x.shape[1] // n
            return [x[:, d * bl:(d + 1) * bl] for d in range(n)]

        cols = jax.tree.map(split, rollout)
        shards = [jax.tree.map(lambda lst: lst[d], cols,
                               is_leaf=lambda v: isinstance(v, list))
                  for d in range(n)]
        return self._assemble([
            jax.tree.map(lambda x, dev=dev: jax.device_put(x, dev), s)
            for s, dev in zip(shards, self._devices)])

    def _load_behavior(self, behavior_params):
        return [jax.tree.map(lambda x, dev=dev: jax.device_put(
            jnp.asarray(x), dev), p)
            for p, dev in zip(behavior_params, self._devices)]


# ---------------------------------------------------------------------------
# Off-policy replay composition


class ReplaySource:
    """Compose a replay buffer over any ``RolloutSource``.

    Every ``next_batch`` (1) pulls one fresh rollout batch from the inner
    source, (2) inserts its B columns into the buffer, (3) samples
    ``round(B * replay_ratio)`` stored rollouts and (4) emits the
    concatenation along the batch axis, tagged with a per-column
    ``is_replay`` mask. Replayed columns keep the ``behavior_logits`` /
    ``behavior_logprob`` recorded when they were generated, so the V-trace
    importance weights in the learner stay correct for stale data — no
    special-casing in the loss beyond the optional CLEAR terms
    (core/losses.py) gated on ``is_replay``.

    ``replay_ratio`` is replayed:fresh — 1.0 means a 1:1 mixed batch of
    2B columns. ``frames_per_batch`` counts only the B fresh columns
    (replayed rows cost no new environment frames; that is the
    sample-efficiency argument). Sampling happens BEFORE the fresh batch
    is inserted, so replayed rows always predate the current step — except
    the very first batch, which warm-starts from its own columns.

    ``value_fn(params, obs) -> (T, B) values`` (optional) records the
    acting network's value estimates on every fresh rollout at insert
    time; replayed columns then carry them back as ``behavior_value``, the
    cloning target of the CLEAR value-cloning term (core/losses.py).

    The learner step feeds per-column priorities back through
    ``on_learner_metrics`` (the Runtime calls it after every step when the
    metrics dict carries a ``priority`` vector aligned with the emitted
    columns: fresh first, then replayed).
    """

    def __init__(self, source, buffer, *, replay_ratio: float = 1.0,
                 seed: int = 0, value_fn: Optional[Callable] = None):
        self.inner = source
        self.buffer = buffer
        self.replay_ratio = float(replay_ratio)
        self.frames_per_batch = source.frames_per_batch
        self._value_fn = value_fn
        self._rng = np.random.default_rng(seed)
        self._last_ids: list = []
        self._served = 0        # replayed columns emitted
        self._hits = 0          # ... that were NOT inserted this very step
        self._prio_drops = 0    # priority vectors discarded (shape drift)
        self._prio_warned = False

    def start(self, params) -> None:
        self.inner.start(params)

    def _mix(self, fresh, replayed, b: int, k: int):
        """Mixed batch assembly. Sharded buffers (ShardedReplay) own the
        layout (per-device interleaved, no host concat); the default is a
        fresh-first concatenation. Either way the fresh/replayed schemas
        must agree — a key present on one side only would silently vanish
        from the emitted batch (and the learner would train without it),
        so schema drift fails loudly instead."""
        missing = sorted(set(fresh) - set(replayed))
        extra = sorted(set(replayed) - set(fresh))
        if missing or extra:
            raise KeyError(
                f"fresh/replayed batch schemas diverge: fresh-only keys "
                f"{missing}, replay-only keys {extra} — the emitted batch "
                "would silently drop columns")
        mix = getattr(self.buffer, "mix", None)
        if mix is not None:
            return mix(fresh, replayed)
        batch = {key: jnp.concatenate(
            [jnp.asarray(fresh[key]), jnp.asarray(replayed[key])], axis=1)
            for key in fresh}
        batch["is_replay"] = jnp.zeros((b + k,), bool).at[b:].set(True)
        return batch

    def next_batch(self, params):
        fresh = self.inner.next_batch(params)
        if self._value_fn is not None and "behavior_value" not in fresh:
            # ≈ the behavior network's values (exact up to the source's
            # parameter lag) — the CLEAR value-cloning anchor.
            fresh = dict(fresh, behavior_value=jnp.asarray(
                self._value_fn(params, fresh["obs"][:-1]), jnp.float32))
        b = fresh["action"].shape[1]
        k = int(round(b * self.replay_ratio))
        query = np.asarray(fresh["obs"]) \
            if k and getattr(self.buffer, "needs_query", False) else None
        replayed = None
        if k and len(self.buffer):   # sample strictly-older data first
            replayed, replay_ids = self.buffer.sample(k, self._rng,
                                                      query=query)
        fresh_ids = self.buffer.insert(fresh)
        if k == 0:
            self._last_ids = list(fresh_ids)
            return dict(fresh, is_replay=jnp.zeros((b,), bool))
        if replayed is None:         # first batch: warm-start from itself
            replayed, replay_ids = self.buffer.sample(k, self._rng,
                                                      query=query)
        batch = self._mix(fresh, replayed, b, k)
        # _last_ids must follow the EMITTED column order (the learner's
        # priority vector aligns with it); sharded buffers interleave.
        order = getattr(self.buffer, "emitted_ids", None)
        self._last_ids = order(list(fresh_ids), list(replay_ids)) \
            if order is not None else list(fresh_ids) + list(replay_ids)
        self._served += k
        fresh_set = set(fresh_ids)
        self._hits += sum(1 for i in replay_ids if i not in fresh_set)
        return batch

    def on_learner_metrics(self, step, metrics) -> None:
        """Runtime feedback hook: route the learner's per-column priority
        vector to the slots that produced the last batch. A vector that
        does not align with the emitted columns cannot be routed — that
        silently degrades elite replay to uniform, so it warns (once) and
        counts the drop in ``stats()``."""
        del step
        prio = metrics.get("priority") if hasattr(metrics, "get") else None
        if prio is None or not self._last_ids:
            return
        prio = np.asarray(prio, np.float64)
        if prio.shape[0] != len(self._last_ids):
            self._prio_drops += 1
            if not self._prio_warned:
                self._prio_warned = True
                warnings.warn(
                    f"replay priority vector has {prio.shape[0]} entries "
                    f"but the last batch emitted {len(self._last_ids)} "
                    "columns; feedback dropped — elite replay is degrading "
                    "to uniform (drops counted in stats()['replay_"
                    "priority_drops'])", RuntimeWarning, stacklevel=2)
            return
        self.buffer.update_priorities(self._last_ids, prio)

    def stats(self):
        s = {f"replay_{k}": v for k, v in self.buffer.stats().items()}
        s["replay_hit_rate"] = self._hits / max(self._served, 1)
        s["replay_priority_drops"] = float(self._prio_drops)
        return s

    # -- SourceState protocol --------------------------------------------------

    def state_dict(self) -> Dict[str, Any]:
        """Nested checkpoint: inner-source state + buffer slots/priorities
        + the sampling RNG and feedback bookkeeping. ``_last_ids`` entries
        may be ints or (device, ticket) tuples (ShardedReplay) — both
        round-trip through the structured checkpoint encoder."""
        return {
            "kind": type(self).__name__,
            "inner": self.inner.state_dict(),
            "buffer": self.buffer.state_dict(),
            "rng": self._rng.bit_generator.state,
            "last_ids": list(self._last_ids),
            "served": self._served,
            "hits": self._hits,
            "prio_drops": self._prio_drops,
        }

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        _check_kind(state, self)
        self.inner.load_state_dict(state["inner"])
        self.buffer.load_state_dict(state["buffer"])
        rng = np.random.default_rng()
        rng.bit_generator.state = state["rng"]
        self._rng = rng
        self._last_ids = [tuple(int(j) for j in i)
                          if isinstance(i, (tuple, list)) else int(i)
                          for i in state["last_ids"]]
        self._served = int(state["served"])
        self._hits = int(state["hits"])
        self._prio_drops = int(state["prio_drops"])

    def stop(self) -> None:
        """Stop the inner source and recycle every buffer slot back to the
        free list — even when the learner died mid-batch."""
        try:
            self.inner.stop()
        finally:
            self._last_ids = []
            self.buffer.clear()


# ---------------------------------------------------------------------------
# Host-loop (MonoBeast/PolyBeast) actors


class HostLoopSource:
    """Actor threads + inference queue + learner queue behind the contract.

    ``next_batch(params)`` publishes the new params to the inference thread
    (actors pick them up on their next policy evaluation — the natural
    asynchronous parameter lag of the host architecture) and blocks until
    the learner queue yields a stacked batch.

    ``mesh``: when set, every learner-queue batch is split across the data
    mesh on its batch dimension (``jax.device_put`` with the shared rollout
    sharding table) before it is handed to the learner — the host actor
    architecture feeding the data-parallel sharded learner. The transfer
    replaces the single-device host→device copy the unsharded path already
    paid; there is no extra resharding step.

    SourceState: Python thread scheduling (which actor's rollout lands in
    which batch slot) is not replayable, so the host path cannot promise
    bit-exact resume. ``state_dict`` records only the source kind; actors
    restart fresh on resume while learner + replay state restore exactly.
    """

    def __init__(self, env, apply_fn, *, num_actors: int,
                 unroll_length: int, batch_size: int, seed: int = 0,
                 inference_batch: Optional[int] = None,
                 inference_timeout_ms: float = 5.0, max_items: int = 128,
                 batch_timeout_s: float = 60.0, mesh=None):
        self._env = env
        self._apply_fn = apply_fn
        self.num_actors = num_actors
        self.unroll_length = unroll_length
        self.batch_size = batch_size
        self.frames_per_batch = unroll_length * batch_size
        self.seed = seed
        self._inference_batch = inference_batch or num_actors
        self._inference_timeout_ms = inference_timeout_ms
        self._max_items = max_items
        self._batch_timeout_s = batch_timeout_s
        self._params = None
        self._pool = None
        self._inference_thread = None
        self._mesh = mesh
        self._shardings = None
        if mesh is not None:
            from repro.distributed.sharding import rollout_batch_shardings
            n = mesh.devices.size
            if batch_size % n != 0:
                raise ValueError(f"batch {batch_size} not divisible by "
                                 f"mesh size {n}")
            self._shardings = rollout_batch_shardings(mesh)

    def start(self, params) -> None:
        from repro.core.actor_pool import ActorPool, start_inference_thread
        from repro.core.batcher import BatchingQueue, DynamicBatcher
        from repro.envs.base import HostEnv

        self._params = params
        policy = jax.jit(
            lambda p, obs: self._apply_fn(p, obs).policy_logits)
        self.inference = DynamicBatcher(
            max_batch_size=self._inference_batch,
            timeout_ms=self._inference_timeout_ms)
        self.learner_queue = BatchingQueue(
            self.batch_size, batch_dim=1, max_items=self._max_items)
        self._pool = ActorPool(
            lambda seed: HostEnv(self._env, seed), self.num_actors,
            self.unroll_length, self.inference, self.learner_queue,
            seed=self.seed)
        self._inference_thread = start_inference_thread(
            self.inference,
            lambda obs: np.asarray(policy(self._params, jnp.asarray(obs))))
        self._pool.start()

    def next_batch(self, params):
        if self._pool is None:
            self.start(params)
        self._params = params
        batch = self.learner_queue.get(timeout=self._batch_timeout_s)
        if batch is None:
            raise TimeoutError(
                f"no learner batch within {self._batch_timeout_s}s "
                f"({self.num_actors} actors, queue "
                f"size {self.learner_queue.size()})")
        if self._shardings is None:
            return {k: jnp.asarray(v) for k, v in batch.items()}
        # split the stacked host batch over the data mesh (batch dim 1)
        return {k: jax.device_put(np.asarray(v),
                                  self._shardings[np.ndim(v)])
                for k, v in batch.items()}

    def stop(self) -> None:
        """Stop the actor pool AND the inference thread. The pool closes
        the DynamicBatcher (unblocking the thread's ``get_batch``), but the
        thread itself must be joined — otherwise it lingers, evaluating the
        policy with the stale ``self._params`` of the stopped run."""
        pool, self._pool = self._pool, None
        if pool is not None:
            pool.stop()
        thread, self._inference_thread = self._inference_thread, None
        if thread is not None:
            thread.join(timeout=5.0)
            if thread.is_alive():
                # warn, don't raise: stop() runs in Runtime's finally, and
                # raising here would mask the root-cause exception (e.g.
                # the actor TimeoutError a wedged policy eval produced).
                warnings.warn("inference thread did not exit within 5s of "
                              "stop()", RuntimeWarning, stacklevel=2)
        self._params = None

    def state_dict(self) -> Dict[str, Any]:
        return {"kind": type(self).__name__}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        _check_kind(state, self)


# ---------------------------------------------------------------------------
# LLM-policy token-MDP actors (DESIGN.md §2)


def token_task_reward(tokens, vocab_size: int, a_mod: int = 5,
                      b_mod: int = 3):
    """The synthetic token-MDP reward: +1 when token t+1 equals the affine
    target (a*token_t + b) mod V. tokens (B, S+1) -> reward (B, S)."""
    target = (a_mod * tokens[:, :-1] + b_mod) % vocab_size
    return (tokens[:, 1:] == target).astype(jnp.float32)


class GeneratorSource:
    """Episodes from the autoregressive decode path: the LM *is* the policy,
    tokens are actions, and the recorded sampling log-probs are the behavior
    policy outputs V-trace needs. Emitted time-major per the contract
    (obs[t] is the token consumed at step t; action[t] == obs[t+1]).

    Runs through ``generate.DecodeSession`` — the same slot API (and the
    same compiled step) the serving loop drives. Each episode admits every
    slot, steps the session in lockstep, then evicts; the decode cache
    layout is pinned to ``launch.specs.cache_specs`` when a mesh is given.
    The attention/SSD impls come from the config (see ImplContext)."""

    def __init__(self, cfg, *, batch_size: int, episode_length: int, key,
                 reward_fn: Optional[Callable] = None,
                 temperature: float = 1.0, mesh=None, rules=None):
        self._cfg = cfg
        self.batch_size = batch_size
        self.episode_length = episode_length
        self.frames_per_batch = batch_size * episode_length
        self._key = key
        self._reward_fn = reward_fn or (
            lambda toks: token_task_reward(toks, cfg.vocab_size))
        self._temperature = temperature
        self._mesh, self._rules = mesh, rules
        self._session = None

    def start(self, params) -> None:
        del params

    def _get_session(self, params):
        from repro.core import generate as gen_lib
        if self._session is None:
            self._session = gen_lib.DecodeSession(
                params, self._cfg, max_batch=self.batch_size,
                max_len=self.episode_length + 1, mesh=self._mesh,
                rules=self._rules)
        self._session.params = params   # follow the learner's updates
        return self._session

    def next_batch(self, params):
        b, t = self.batch_size, self.episode_length
        self._key, k_prompt, k_gen = jax.random.split(self._key, 3)
        prompt = jax.random.randint(k_prompt, (b, 1), 0,
                                    self._cfg.vocab_size)
        sess = self._get_session(params)
        keys = jax.random.split(k_gen, b)
        prompt_np = np.asarray(prompt)
        # batched admit: every episode reset is ONE device dispatch (the
        # prompts share a prefill bucket), not one per slot
        first = sess.prefill_many(range(b), list(prompt_np), keys=keys,
                                  temperature=self._temperature)
        toks = [[f["token"] for f in first]]          # time-major lists
        lps = [[f["logprob"] for f in first]]
        for _ in range(t - 1):
            o = sess.step()
            toks.append(list(o["token"]))
            lps.append(list(o["logprob"]))
        for i in range(b):
            sess.evict(i)
        gen_toks = jnp.asarray(np.asarray(toks, np.int32).T)   # (B, T)
        logprob = jnp.asarray(np.asarray(lps, np.float32).T)   # (B, T)
        ep = {"logprob": logprob}
        tokens = jnp.concatenate([prompt, gen_toks], axis=1)   # (B, T+1)
        reward = self._reward_fn(tokens)                       # (B, T)
        done = jnp.zeros((b, t), bool).at[:, -1].set(True)
        tm = lambda x: jnp.swapaxes(x, 0, 1)  # noqa: E731
        return {
            "obs": tm(tokens).astype(jnp.int32),               # (T+1, B)
            "action": tm(tokens[:, 1:]).astype(jnp.int32),
            "behavior_logprob": tm(ep["logprob"]).astype(jnp.float32),
            "reward": tm(reward),
            "done": tm(done),
        }

    def stop(self) -> None:
        pass

    def state_dict(self) -> Dict[str, Any]:
        return {"kind": type(self).__name__, "key": np.asarray(self._key)}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        _check_kind(state, self)
        self._key = jnp.asarray(state["key"])


def lm_rl_step_from_rollout(lm_train_step: Callable) -> Callable:
    """Adapt ``learner.make_lm_train_step`` (batch-major token dict) to the
    canonical time-major rollout emitted by GeneratorSource."""

    def step(params, opt_state, step_i, rollout):
        bm = lambda x: jnp.swapaxes(x, 0, 1)  # noqa: E731
        batch = {
            "tokens": bm(rollout["obs"]),
            "behavior_logprob": bm(rollout["behavior_logprob"]),
            "reward": bm(rollout["reward"]),
            "done": bm(rollout["done"]),
        }
        return lm_train_step(params, opt_state, step_i, batch)

    return step


# ---------------------------------------------------------------------------
# Supervised data (LM pretraining)


class DataSource:
    """A RolloutSource over any iterator of ready batches — the non-RL
    substrate (LM pretraining) runs through the same Runtime loop.

    SourceState: when the iterator itself is checkpointable (exposes
    ``state_dict``/``load_state_dict``, e.g. data.PackedBatchIterator's
    seed+offset), its state rides inside the source state — extending the
    bit-exact ``--resume`` guarantee to ``--mode lm``. Plain iterators
    checkpoint as stateless (the pre-protocol behavior)."""

    def __init__(self, iterator: Iterator, *, frames_per_batch: int = 0,
                 transform: Optional[Callable] = None,
                 close: Optional[Callable] = None):
        self._it = iterator
        self.frames_per_batch = frames_per_batch
        self._transform = transform
        self._close = close

    def start(self, params) -> None:
        del params

    def next_batch(self, params):
        batch = next(self._it)
        if self._transform is not None:
            batch = self._transform(batch)
        return batch

    def stop(self) -> None:
        if self._close is not None:
            self._close()

    def state_dict(self) -> Dict[str, Any]:
        # Iterator position is owned by the iterator; checkpoint it when
        # the iterator answers the protocol (class docstring).
        state_fn = getattr(self._it, "state_dict", None)
        return {"kind": type(self).__name__,
                "iterator": None if state_fn is None else state_fn()}

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        _check_kind(state, self)
        it_state = state.get("iterator") if hasattr(state, "get") else None
        if it_state is None:
            return   # stateless iterator / pre-protocol checkpoint
        load_fn = getattr(self._it, "load_state_dict", None)
        if load_fn is None:
            raise ValueError(
                "checkpoint carries iterator state "
                f"({it_state.get('kind')!r}) but this run's iterator is "
                "not checkpointable — resume with the same data pipeline")
        load_fn(it_state)
