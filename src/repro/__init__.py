"""JAXBeast: a JAX platform for distributed RL (TorchBeast reproduction)."""
__version__ = "1.0.0"
