from repro.models import model, blocks, attention, mlp, moe, mamba, xlstm, convnet, common  # noqa: F401
