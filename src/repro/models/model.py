"""Top-level decoder model: embed -> lax.scan over stacked super-blocks
(-> optional zamba-style shared global block per group) -> final norm ->
heads (LM logits over vocab = policy logits; scalar baseline for IMPALA).

All params are AxisParam trees at init; call ``common.split_params`` to get
(values, logical_axes). Apply functions take the *values* tree.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.models.common import (make_norm, param, sinusoidal_pos_emb,
                                 softcap, split_params, stack_init)

SHARED_PATTERN = (("attn", "swiglu"),)  # zamba-style shared global block


def _constrain_act(x):
    from repro.distributed.sharding import constrain
    return constrain(x, ("act_batch", "act_seq", "act_embed"))


def model_init(key, cfg):
    """Returns an AxisParam tree for the full model."""
    ks = jax.random.split(key, 6)
    norm_init, _ = make_norm(cfg)
    p = {
        # 1/sqrt(d): keeps initial logits O(1) for both tied (h @ embed.T)
        # and untied heads -> near-uniform initial policy (entropy ~ log V),
        # which IMPALA's importance ratios need at step 0.
        "embed": param(ks[0], (cfg.vocab_size, cfg.d_model),
                       ("vocab", "embed"), scale=cfg.d_model ** -0.5),
        "blocks": stack_init(blocks.block_init, ks[1], cfg.num_groups, cfg),
        "final_norm": norm_init(ks[2], cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = param(ks[3], (cfg.d_model, cfg.vocab_size),
                             ("embed", "vocab"))
    if cfg.shared_attn_every:
        p["shared"] = blocks.block_init(ks[4], cfg, pattern=SHARED_PATTERN)
    if cfg.baseline_head:
        p["baseline"] = param(ks[5], (cfg.d_model,), ("embed",),
                              scale=cfg.d_model ** -0.5)
    return p


def init(key, cfg):
    """Convenience: returns (params_values, logical_axes)."""
    return split_params(model_init(key, cfg))


def _embed(params, cfg, tokens, positions):
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.pos_emb == "sinusoidal":
        x = x + sinusoidal_pos_emb(positions, cfg.d_model).astype(x.dtype)
    return x


def unembed_matrix(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


def logits_from_hidden(params, cfg, h):
    w = unembed_matrix(params, cfg)
    logits = jnp.einsum("...d,dv->...v", h, w.astype(h.dtype),
                        preferred_element_type=jnp.float32)
    if cfg.final_logit_softcap:
        logits = softcap(logits, cfg.final_logit_softcap)
    return logits  # fp32


def baseline_from_hidden(params, cfg, h):
    if not cfg.baseline_head:
        return None
    return jnp.einsum("...d,d->...", h.astype(jnp.float32),
                      params["baseline"].astype(jnp.float32))


def forward(params, tokens, *, cfg, vision=None, impl=None,
            build_cache=False, cache_seq_len=None):
    """Forward over a full sequence.

    tokens: (B, S) int32. vision: (B, Sv, d) patch embeddings (VLM stub).
    Returns (hidden (B,S,d), aux, cache|None). aux = (lb, z, dropped) summed
    over all MoE layers.
    """
    s = tokens.shape[1]
    positions = jnp.arange(s)
    x = _embed(params, cfg, tokens, positions)
    dtype = x.dtype

    def body(carry, block_params):
        x, aux = carry
        # residual-stream constraint: under seq-parallel rules the saved
        # scan carries are sharded over the model axis (no-op otherwise)
        x = _constrain_act(x)
        x, baux, cache = blocks.block_apply(
            block_params, x, cfg=cfg, positions=positions, vision=vision,
            impl=impl, build_cache=build_cache, seq_len=cache_seq_len,
            dtype=dtype)
        if cfg.shared_attn_every:
            x, saux, scache = blocks.block_apply(
                params["shared"], x, cfg=cfg, positions=positions,
                pattern=SHARED_PATTERN, impl=impl, build_cache=build_cache,
                seq_len=cache_seq_len, dtype=dtype)
            baux = blocks._add_aux(baux, saux)
            if build_cache:
                cache = {"block": cache, "shared": scache}
        elif build_cache:
            cache = {"block": cache}
        return (x, blocks._add_aux(aux, baux)), cache

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux), caches = jax.lax.scan(body, (x, blocks.zero_aux()),
                                    params["blocks"])
    _, norm_fn = make_norm(cfg)
    x = norm_fn(params["final_norm"], x)
    return x, aux, (caches if build_cache else None)


def cache_init(cfg, batch, seq_len, dtype=None):
    """Zero decode cache: per-group stacked pytree matching ``forward``'s."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    one = {"block": blocks.block_cache_init(cfg, batch, seq_len, dtype)}
    if cfg.shared_attn_every:
        one["shared"] = blocks.block_cache_init(cfg, batch, seq_len, dtype,
                                                pattern=SHARED_PATTERN)
    return jax.tree.map(
        lambda a: jnp.zeros((cfg.num_groups,) + a.shape, a.dtype), one)


def prefill(params, tokens, *, cfg, vision=None, impl=None, cache_seq_len):
    """Prefill: forward + build decode caches.

    Returns (hidden (B,S,d), aux, cache). cache leaves have leading
    num_groups axis (scan-stacked).
    """
    return forward(params, tokens, cfg=cfg, vision=vision, impl=impl,
                   build_cache=True, cache_seq_len=cache_seq_len)


def decode_step(params, tokens, cache, pos, *, cfg, unroll=False,
                impl=None):
    """One-token decode. tokens: (B,1) int32; pos: scalar int32 (position of
    this token; lockstep decode) or (B,) int32 (per-slot positions — the
    continuous-batching serve path). Returns (hidden (B,1,d), new_cache).

    unroll=True (the production serve path): a static Python loop over
    groups with per-layer in-place cache writes — lax.scan would carry the
    whole cache as xs/ys and double-buffer it (2x cache HBM); the unrolled
    form lets XLA alias the donated cache buffer layer by layer.
    """
    pos = jnp.asarray(pos)
    x = _embed(params, cfg, tokens, pos[:, None] if pos.ndim else pos[None])

    def body(x, block_params, cache_slice):
        x, nc = blocks.block_decode(block_params, x, cache_slice["block"],
                                    cfg=cfg, pos=pos, impl=impl)
        nc = {"block": nc}
        if cfg.shared_attn_every:
            x, nsc = blocks.block_decode(params["shared"], x,
                                         cache_slice["shared"], cfg=cfg,
                                         pos=pos, pattern=SHARED_PATTERN,
                                         impl=impl)
            nc["shared"] = nsc
        return x, nc

    if unroll:
        # cache-as-carry: the scan carries the WHOLE cache and each step
        # dynamic-updates its group slice in place. XLA aliases while-loop
        # carries (same shape in/out), so the donated cache buffer is
        # updated without the 2x double-buffering that cache-as-xs/ys
        # (stacked ys allocation) costs.
        def carry_body(carry, inputs):
            x, full_cache = carry
            g, bp = inputs
            cs = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, g, 0,
                                                       keepdims=False),
                full_cache)
            x, nc = body(x, bp, cs)
            full_cache = jax.tree.map(
                lambda full, upd: jax.lax.dynamic_update_index_in_dim(
                    full, upd.astype(full.dtype), g, 0), full_cache, nc)
            return (x, full_cache), None

        (x, new_cache), _ = jax.lax.scan(
            carry_body, (x, cache),
            (jnp.arange(cfg.num_groups), params["blocks"]))
    else:
        x, new_cache = jax.lax.scan(
            lambda x, xs: body(x, xs[0], xs[1]),
            x, (params["blocks"], cache))
    _, norm_fn = make_norm(cfg)
    x = norm_fn(params["final_norm"], x)
    return x, new_cache


# ---------------------------------------------------------------------------
# convenience heads for drivers/tests
# ---------------------------------------------------------------------------

def apply_lm(params, tokens, *, cfg, vision=None, impl=None):
    """(B,S) -> (logits fp32 (B,S,V), baseline (B,S)|None, aux)."""
    h, aux, _ = forward(params, tokens, cfg=cfg, vision=vision, impl=impl)
    return logits_from_hidden(params, cfg, h), \
        baseline_from_hidden(params, cfg, h), aux


def serve_step(params, tokens, cache, pos, *, cfg, unroll=False,
               impl=None):
    """(B,1) + cache -> (logits fp32 (B,1,V), baseline, new_cache)."""
    h, new_cache = decode_step(params, tokens, cache, pos, cfg=cfg,
                               unroll=unroll, impl=impl)
    return (logits_from_hidden(params, cfg, h),
            baseline_from_hidden(params, cfg, h), new_cache)
