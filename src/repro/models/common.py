"""Shared model building blocks: params-with-logical-axes, norms, rotary.

Parameters are plain pytrees (nested dicts of jnp arrays). During init every
leaf is created as an ``AxisParam(value, axes)`` carrying *logical* sharding
axes (MaxText-style); ``split_params`` separates the value tree from the axes
tree so the distributed layer can map logical axes -> mesh axes.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class AxisParam(NamedTuple):
    value: Any
    axes: Tuple[str, ...]


def param(key, shape, axes, dtype=jnp.float32, scale=None, init="normal"):
    """Create an AxisParam. ``scale=None`` -> 1/sqrt(fan_in) (first dim)."""
    assert len(shape) == len(axes), (shape, axes)
    if init == "zeros":
        v = jnp.zeros(shape, dtype)
    elif init == "ones":
        v = jnp.ones(shape, dtype)
    else:
        if scale is None:
            scale = 1.0 / np.sqrt(max(1, shape[0]))
        v = scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)
    return AxisParam(v, tuple(axes))


def is_axis_param(x):
    return isinstance(x, AxisParam)


def split_params(tree):
    """Split a tree of AxisParam into (values, axes) trees."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_axis_param)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_axis_param)
    return values, axes


def stack_init(init_fn, key, n, *args, **kwargs):
    """Stack ``n`` independent inits along a leading 'layers' logical axis.

    ``init_fn(key, *args, **kwargs)`` must return a tree of AxisParam. Only
    the values are vmapped (string axes are not valid vmap leaves); the axes
    tree is taken from a prototype call.
    """
    proto = init_fn(jax.random.PRNGKey(0), *args, **kwargs)
    _, axes = split_params(proto)
    keys = jax.random.split(key, n)
    values = jax.vmap(lambda k: split_params(init_fn(k, *args, **kwargs))[0])(keys)
    return jax.tree.map(
        lambda v, ax: AxisParam(v, ("layers",) + tuple(ax)), values, axes)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(key, dim, axes=("embed",)):
    del key
    return {"scale": param(None, (dim,), axes, init="zeros")}


def rmsnorm(params, x, eps=1e-6):
    """RMSNorm with (1 + scale) parameterisation (gemma/qwen style), fp32 stats."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


def layernorm_init(key, dim, axes=("embed",)):
    del key
    return {
        "scale": param(None, (dim,), axes, init="zeros"),
        "bias": param(None, (dim,), axes, init="zeros"),
    }


def layernorm(params, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * (1.0 + params["scale"].astype(jnp.float32)) + params["bias"].astype(jnp.float32)
    return y.astype(dt)


def make_norm(cfg):
    if cfg.norm == "layernorm":
        return layernorm_init, lambda p, x: layernorm(p, x, cfg.norm_eps)
    return rmsnorm_init, lambda p, x: rmsnorm(p, x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim, theta):
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim/2,)


def apply_rope(x, positions, theta):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos_emb(positions, dim):
    """(..., S) int -> (..., S, dim) float32 sinusoidal embedding."""
    half = dim // 2
    freqs = jnp.exp(-np.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------

def softcap(x, cap):
    return cap * jnp.tanh(x / cap)


def dense(w, x):
    """x @ w with fp32 accumulation on the MXU."""
    return jnp.einsum("...i,io->...o", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def dtype_of(cfg):
    return jnp.dtype(cfg.dtype)
