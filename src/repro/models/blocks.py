"""Super-block composition: each architecture is ``num_groups`` repetitions
of ``cfg.block_pattern`` (a tuple of (mixer, ffn) layer specs). One
super-block's params/caches form the pytree that ``model.py`` stacks and
scans over.

Residual wiring: pre-norm (gemma2 adds sandwich post-norms). MoE aux losses
are returned as a summed (load_balance, z_loss, dropped) triple.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention, mamba, mlp, moe, xlstm
from repro.models.common import make_norm

ATTN_KINDS = ("attn", "local_attn", "swa_attn", "xattn")


def zero_aux():
    """(load_balance, z_loss, dropped_frac) accumulator — created inside
    traced code (no device arrays at import time)."""
    return (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
            jnp.zeros((), jnp.float32))


def _mixer_init(key, cfg, kind):
    if kind in ATTN_KINDS:
        return attention.attn_init(key, cfg, kind)
    if kind == "mamba":
        return mamba.mamba_init(key, cfg)
    if kind == "mlstm":
        return xlstm.mlstm_init(key, cfg)
    if kind == "slstm":
        return xlstm.slstm_init(key, cfg)
    raise ValueError(kind)


def block_init(key, cfg, pattern=None):
    """Params for one super-block."""
    pattern = pattern if pattern is not None else cfg.block_pattern
    norm_init, _ = make_norm(cfg)
    p = {}
    for idx, (mixer, ffn) in enumerate(pattern):
        key, k1, k2, k3, k4 = jax.random.split(key, 5)
        layer = {"pre_norm": norm_init(k3, cfg.d_model),
                 "mixer": _mixer_init(k1, cfg, mixer)}
        if cfg.sandwich_norm:
            layer["post_norm"] = norm_init(k4, cfg.d_model)
        if ffn != "none":
            key, k5, k6, k7 = jax.random.split(key, 4)
            layer["ffn_pre_norm"] = norm_init(k5, cfg.d_model)
            if ffn == "moe":
                layer["ffn"] = moe.moe_init(k6, cfg)
            else:
                layer["ffn"] = mlp.mlp_init(k6, cfg, ffn)
            if cfg.sandwich_norm:
                layer["ffn_post_norm"] = norm_init(k7, cfg.d_model)
        p[f"l{idx}"] = layer
    return p


def _add_aux(a, b):
    return tuple(x + y for x, y in zip(a, b))


def _apply_ffn(layer, x, cfg, ffn, norm_fn):
    from repro.distributed.sharding import gather_seq
    aux = zero_aux()
    h = gather_seq(norm_fn(layer["ffn_pre_norm"], x))
    if ffn == "moe":
        h, moe_aux = moe.moe_apply(layer["ffn"], h, cfg)
        aux = (moe_aux.load_balance, moe_aux.z_loss, moe_aux.dropped_frac)
    else:
        h = mlp.mlp_apply(layer["ffn"], h, ffn)
    if cfg.sandwich_norm:
        h = norm_fn(layer["ffn_post_norm"], h)
    return x + h, aux


def block_apply(params, x, *, cfg, positions, pattern=None, vision=None,
                impl=None, build_cache=False, seq_len=None, dtype=None):
    """Full-sequence super-block. Returns (x, aux, cache|None).

    build_cache=True (prefill): returns the decode cache slice for this block.
    """
    pattern = pattern if pattern is not None else cfg.block_pattern
    _, norm_fn = make_norm(cfg)
    aux = zero_aux()
    cache = {} if build_cache else None

    from repro.distributed.sharding import gather_seq

    def layer_fn(layer, x, mixer, ffn):
        aux = zero_aux()
        lcache = None
        # gather the seq-parallel residual HERE, on the bf16 norm output
        h = gather_seq(norm_fn(layer["pre_norm"], x))
        if mixer in ATTN_KINDS:
            kv_src = vision if mixer == "xattn" else None
            h, kv = attention.attn_apply(layer["mixer"], h, cfg=cfg,
                                         kind=mixer, positions=positions,
                                         kv_src=kv_src, impl=impl)
            if build_cache:
                lcache = attention.attn_prefill_cache(
                    cfg, mixer, kv, seq_len, dtype)
        elif mixer == "mamba":
            h, st = mamba.mamba_apply(layer["mixer"], h, cfg,
                                      return_state=build_cache)
            lcache = st
        elif mixer == "mlstm":
            h, st = xlstm.mlstm_apply(layer["mixer"], h, cfg,
                                      return_state=build_cache)
            lcache = st
        elif mixer == "slstm":
            h, st = xlstm.slstm_apply(layer["mixer"], h, cfg,
                                      return_state=build_cache)
            lcache = st
        if cfg.sandwich_norm:
            h = norm_fn(layer["post_norm"], h)
        x = x + h
        if ffn != "none":
            x, ffn_aux = _apply_ffn(layer, x, cfg, ffn, norm_fn)
            aux = _add_aux(aux, ffn_aux)
        return x, aux, lcache

    # nested remat: for multi-layer super-blocks (llama-vision's 5-layer
    # group, gemma2's pairs) each LAYER is its own checkpoint region, so
    # the block backward holds one layer's residuals at a time.
    if cfg.remat and len(pattern) > 1 and not build_cache:
        layer_fn = jax.checkpoint(layer_fn, static_argnums=(2, 3))

    for idx, (mixer, ffn) in enumerate(pattern):
        x, layer_aux, lcache = layer_fn(params[f"l{idx}"], x, mixer, ffn)
        aux = _add_aux(aux, layer_aux)
        if build_cache:
            cache[f"l{idx}"] = lcache
    return x, aux, cache


def block_decode(params, x, cache, *, cfg, pos, pattern=None, impl=None):
    """One-token decode through a super-block. Returns (x, new_cache)."""
    pattern = pattern if pattern is not None else cfg.block_pattern
    _, norm_fn = make_norm(cfg)
    new_cache = {}
    for idx, (mixer, ffn) in enumerate(pattern):
        layer = params[f"l{idx}"]
        lcache = cache[f"l{idx}"]
        h = norm_fn(layer["pre_norm"], x)
        if mixer in ATTN_KINDS:
            h, nc = attention.attn_decode(layer["mixer"], h, lcache,
                                          cfg=cfg, kind=mixer, pos=pos,
                                          impl=impl)
        elif mixer == "mamba":
            h, nc = mamba.mamba_decode(layer["mixer"], h, lcache, cfg)
        elif mixer == "mlstm":
            h, nc = xlstm.mlstm_decode(layer["mixer"], h, lcache, cfg)
        elif mixer == "slstm":
            h, nc = xlstm.slstm_decode(layer["mixer"], h, lcache, cfg)
        new_cache[f"l{idx}"] = nc
        if cfg.sandwich_norm:
            h = norm_fn(layer["post_norm"], h)
        x = x + h
        if ffn != "none":
            x, _ = _apply_ffn(layer, x, cfg, ffn, norm_fn)
    return x, new_cache


def block_cache_init(cfg, batch, seq_len, dtype, pattern=None):
    """Zero-initialised decode cache for one super-block."""
    pattern = pattern if pattern is not None else cfg.block_pattern
    cache = {}
    for idx, (mixer, _) in enumerate(pattern):
        if mixer in ATTN_KINDS:
            cache[f"l{idx}"] = attention.attn_cache_init(
                cfg, mixer, batch, seq_len, dtype)
        elif mixer == "mamba":
            cache[f"l{idx}"] = mamba.mamba_cache_init(cfg, batch, dtype)
        elif mixer == "mlstm":
            cache[f"l{idx}"] = xlstm.mlstm_state_init(cfg, batch)
        elif mixer == "slstm":
            cache[f"l{idx}"] = xlstm.slstm_state_init(cfg, batch)
    return cache
