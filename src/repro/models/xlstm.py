"""xLSTM mixers: mLSTM (matrix memory, chunked-parallel) and sLSTM (scalar
memory, sequential recurrence) [arXiv:2405.04517].

TPU adaptation: mLSTM uses the chunkwise-parallel formulation (intra-chunk
quadratic matmuls + inter-chunk recurrent state (C, n, m)) so training maps
onto the MXU; sLSTM is a true recurrence (hidden-state feedback through the
gates) and runs as lax.scan over time — at the assigned scale (d=768, 12L)
this is memory-bound but cheap.

State conventions:
  mLSTM: C (B,H,dk,dv), n (B,H,dk), m (B,H)          [log-space stabiliser m]
  sLSTM: c,n,h (B,H,dh), m (B,H,dh)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import param, rmsnorm


def _dims(cfg):
    h = cfg.num_heads
    dh = cfg.d_model // h
    return h, dh


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg, kind="mlstm"):
    del kind
    d = cfg.d_model
    h, dh = _dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "wq": param(ks[0], (d, h, dh), ("embed", "heads", "head_dim")),
        "wk": param(ks[1], (d, h, dh), ("embed", "heads", "head_dim")),
        "wv": param(ks[2], (d, h, dh), ("embed", "heads", "head_dim")),
        "wi": param(ks[3], (d, h), ("embed", "heads"), scale=d ** -0.5),
        "wf": param(ks[4], (d, h), ("embed", "heads"), scale=d ** -0.5),
        "bf": param(None, (h,), ("heads",), init="ones"),  # forget-bias > 0
        "wo_gate": param(ks[5], (d, d), ("embed", "embed2")),
        "norm": param(None, (d,), ("embed",), init="zeros"),
        "wo": param(jax.random.fold_in(ks[5], 1), (d, d), ("embed", "embed2")),
    }


def _mlstm_qkvif(params, x):
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"],
                   preferred_element_type=jnp.float32)
    k = jnp.einsum("bsd,dhe->bshe", x, params["wk"],
                   preferred_element_type=jnp.float32)
    v = jnp.einsum("bsd,dhe->bshe", x, params["wv"],
                   preferred_element_type=jnp.float32)
    i_pre = jnp.einsum("bsd,dh->bsh", x, params["wi"],
                       preferred_element_type=jnp.float32)
    f_pre = jnp.einsum("bsd,dh->bsh", x, params["wf"],
                       preferred_element_type=jnp.float32) + params["bf"]
    return q, k, v, i_pre, f_pre


def mlstm_state_init(cfg, batch):
    h, dh = _dims(cfg)
    return {
        "C": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.zeros((batch, h), jnp.float32),
    }


def mlstm_apply(params, x, cfg, state=None, return_state=False):
    """Chunkwise-parallel mLSTM. x: (B,S,d) -> (y, state|None)."""
    b, s, d = x.shape
    h, dh = _dims(cfg)
    L = min(cfg.xlstm_chunk, s)
    assert s % L == 0
    nc = s // L
    scale = dh ** -0.5

    q, k, v, i_pre, f_pre = _mlstm_qkvif(params, x)
    lf = jax.nn.log_sigmoid(f_pre)                              # (B,S,H)

    def rs(t):  # chunk-major reshape (nc, B, L, ...)
        return t.reshape((b, nc, L) + t.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, t.ndim + 1)))

    qc, kc, vc = rs(q), rs(k), rs(v)
    ic, lfc = rs(i_pre), rs(lf)

    st = state if state is not None else mlstm_state_init(cfg, b)

    @jax.checkpoint
    def chunk_step(carry, inputs):
        C, n, m = carry
        q_i, k_i, v_i, i_i, lf_i = inputs      # (B,L,H,dh)... gates (B,L,H)
        F = jnp.cumsum(lf_i, axis=1)                            # (B,L,H)
        # log-weight of input s for output l (s<=l): F_l - F_s + i_s
        dmat = (F[:, :, None, :] - F[:, None, :, :]
                + i_i[:, None, :, :])                           # (B,L,S,H)
        mask = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]
        dmat = jnp.where(mask, dmat, -jnp.inf)
        # state contribution log-weight at l: m + F_l
        state_w = m[:, None, :] + F                             # (B,L,H)
        m_loc = jnp.maximum(dmat.max(axis=2), state_w)          # (B,L,H)
        dexp = jnp.exp(dmat - m_loc[:, :, None, :])             # (B,L,S,H)
        sw = jnp.exp(state_w - m_loc)                           # (B,L,H)

        logits = jnp.einsum("blhe,bshe->blsh", q_i, k_i) * scale
        num_intra = jnp.einsum("blsh,bshe->blhe", logits * dexp, v_i)
        num_state = jnp.einsum("blhe,bhef->blhf", q_i * scale, C) \
            * sw[..., None]
        den_intra = jnp.einsum("blsh,bshe->blhe", dexp,
                               k_i)  # sum_s dexp * k_s
        den = jnp.einsum("blhe,blhe->blh", q_i * scale, den_intra) \
            + jnp.einsum("blhe,bhe->blh", q_i * scale, n) * sw
        num = num_intra + num_state
        hout = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_loc))[..., None]

        # state update to end of chunk
        b_last = F[:, -1, :]                                    # (B,H)
        in_w = b_last[:, None, :] - F + i_i                     # (B,L,H)
        m_new = jnp.maximum(m + b_last, in_w.max(axis=1))       # (B,H)
        kv_w = jnp.exp(in_w - m_new[:, None, :])                # (B,L,H)
        C_new = C * jnp.exp(m + b_last - m_new)[..., None, None] + \
            jnp.einsum("blh,blhe,blhf->bhef", kv_w, k_i, v_i)
        n_new = n * jnp.exp(m + b_last - m_new)[..., None] + \
            jnp.einsum("blh,blhe->bhe", kv_w, k_i)
        return (C_new, n_new, m_new), hout

    (C, n, m), ys = jax.lax.scan(
        chunk_step, (st["C"], st["n"], st["m"]), (qc, kc, vc, ic, lfc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh)
    y = y.reshape(b, s, d).astype(x.dtype)

    gate = jax.nn.silu(jnp.einsum("bsd,de->bse", x, params["wo_gate"],
                                  preferred_element_type=jnp.float32))
    y = rmsnorm({"scale": params["norm"]}, y, cfg.norm_eps) * gate.astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, params["wo"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    new_state = {"C": C, "n": n, "m": m}
    return out, (new_state if return_state else None)


def mlstm_decode(params, x, state, cfg):
    """Single-token mLSTM step. x: (B,1,d)."""
    b = x.shape[0]
    h, dh = _dims(cfg)
    scale = dh ** -0.5
    q, k, v, i_pre, f_pre = _mlstm_qkvif(params, x)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                         # (B,H,dh)
    i_t, lf = i_pre[:, 0], jax.nn.log_sigmoid(f_pre[:, 0])      # (B,H)

    m_new = jnp.maximum(lf + state["m"], i_t)
    fw = jnp.exp(lf + state["m"] - m_new)[..., None]
    iw = jnp.exp(i_t - m_new)[..., None]
    C = state["C"] * fw[..., None] + iw[..., None] * \
        jnp.einsum("bhe,bhf->bhef", k, v)
    n = state["n"] * fw + iw * k
    num = jnp.einsum("bhe,bhef->bhf", q * scale, C)
    den = jnp.abs(jnp.einsum("bhe,bhe->bh", q * scale, n))
    hout = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    y = hout.reshape(b, 1, -1).astype(x.dtype)

    gate = jax.nn.silu(jnp.einsum("bsd,de->bse", x, params["wo_gate"],
                                  preferred_element_type=jnp.float32))
    y = rmsnorm({"scale": params["norm"]}, y, cfg.norm_eps) * gate.astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, params["wo"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, {"C": C, "n": n, "m": m_new}


def mlstm_reference(params, x, cfg, state=None):
    """Sequential-oracle mLSTM (step-by-step decode recurrence over S)."""
    b, s, d = x.shape
    st = state if state is not None else mlstm_state_init(cfg, b)
    ys = []
    for t in range(s):
        y, st = mlstm_decode(params, x[:, t:t + 1], st, cfg)
        ys.append(y)
    return jnp.concatenate(ys, axis=1), st


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg, kind="slstm"):
    del kind
    d = cfg.d_model
    h, dh = _dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        # input projections for gates z, i, f, o
        "wx": param(ks[0], (d, 4, h, dh), ("embed", "gates", "heads", "head_dim")),
        # per-head recurrent (block-diagonal) weights
        "wr": param(ks[1], (4, h, dh, dh), ("gates", "heads", "head_dim", "head_dim2"),
                    scale=dh ** -0.5),
        "b": param(None, (4, h, dh), ("gates", "heads", "head_dim"), init="zeros"),
        "norm": param(None, (d,), ("embed",), init="zeros"),
        "up": param(ks[2], (d, 2 * d), ("embed", "mlp")),
        "down": param(ks[3], (d, d), ("mlp", "embed")),
    }


def slstm_state_init(cfg, batch):
    h, dh = _dims(cfg)
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z}


def _slstm_step(params, xt, st):
    """xt: (B,4,H,dh) pre-projected gates input; st: state dict."""
    rec = jnp.einsum("bhe,ghef->bghf", st["h"], params["wr"])
    g = xt + rec + params["b"]                                   # (B,4,H,dh)
    z_pre, i_pre, f_pre, o_pre = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    lf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(lf + st["m"], i_pre)
    fw = jnp.exp(lf + st["m"] - m_new)
    iw = jnp.exp(i_pre - m_new)
    c = fw * st["c"] + iw * z
    n = fw * st["n"] + iw
    hout = o * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "h": hout, "m": m_new}


def slstm_apply(params, x, cfg, state=None, return_state=False):
    """Sequential sLSTM. x: (B,S,d)."""
    b, s, d = x.shape
    st = state if state is not None else slstm_state_init(cfg, b)
    xg = jnp.einsum("bsd,dghe->bsghe", x, params["wx"],
                    preferred_element_type=jnp.float32)          # (B,S,4,H,dh)

    def step(carry, xt):
        new = _slstm_step(params, xt, carry)
        return new, new["h"]

    st_out, hs = jax.lax.scan(step, st, xg.transpose(1, 0, 2, 3, 4))
    y = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    y = rmsnorm({"scale": params["norm"]}, y, cfg.norm_eps)
    u = jnp.einsum("bsd,de->bse", y, params["up"],
                   preferred_element_type=jnp.float32)
    u1, u2 = jnp.split(u, 2, axis=-1)
    y = (u1 * jax.nn.silu(u2)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, params["down"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, (st_out if return_state else None)


def slstm_decode(params, x, state, cfg):
    """Single-token sLSTM step. x: (B,1,d)."""
    b, _, d = x.shape
    xg = jnp.einsum("bsd,dghe->bsghe", x, params["wx"],
                    preferred_element_type=jnp.float32)[:, 0]
    st = _slstm_step(params, xg, state)
    y = st["h"].reshape(b, 1, d).astype(x.dtype)
    y = rmsnorm({"scale": params["norm"]}, y, cfg.norm_eps)
    u = jnp.einsum("bsd,de->bse", y, params["up"],
                   preferred_element_type=jnp.float32)
    u1, u2 = jnp.split(u, 2, axis=-1)
    y = (u1 * jax.nn.silu(u2)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, params["down"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, st
