"""Mamba2 mixer (SSD — state-space duality, chunked matmul form).

TPU adaptation: the chunked SSD formulation (intra-chunk quadratic matmuls +
inter-chunk recurrence over chunk states) maps the selective scan onto the
MXU; the sequential CUDA scan of the original kernel is deliberately NOT
ported (see DESIGN.md §8).

Shapes: x (B,S,d); d_inner = expand*d; H = d_inner/headdim heads, P=headdim,
N = ssm_state. Single B/C group (n_groups=1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import param, rmsnorm


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_head_dim
    return d_in, nheads, cfg.ssm_head_dim, cfg.ssm_state


def mamba_init(key, cfg, kind="mamba"):
    del kind
    d = cfg.d_model
    d_in, nh, p, n = _dims(cfg)
    w = cfg.ssm_conv_width
    ks = jax.random.split(key, 8)
    conv_ch = d_in + 2 * n
    # dt bias init so softplus(dt_bias) spans [1e-3, 1e-1] (mamba default)
    u = jax.random.uniform(ks[5], (nh,), jnp.float32)
    dt0 = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))  # inverse softplus
    return {
        "in_proj_z": param(ks[0], (d, d_in), ("embed", "mlp")),
        "in_proj_x": param(ks[1], (d, d_in), ("embed", "mlp")),
        "in_proj_bc": param(ks[2], (d, 2 * n), ("embed", "ssm_state2")),
        "in_proj_dt": param(ks[3], (d, nh), ("embed", "ssm_heads")),
        "conv_w": param(ks[4], (w, conv_ch), ("conv_width", "conv_ch"),
                        scale=w ** -0.5),
        "conv_b": param(None, (conv_ch,), ("conv_ch",), init="zeros"),
        "dt_bias": param(None, (nh,), ("ssm_heads",), init="zeros")._replace(value=dt_bias),
        "a_log": param(None, (nh,), ("ssm_heads",), init="ones"),
        "d_skip": param(None, (nh,), ("ssm_heads",), init="ones"),
        "norm": param(None, (d_in,), ("mlp",), init="zeros"),
        "out_proj": param(ks[6], (d_in, d), ("mlp", "embed")),
    }


def _conv1d(x, w, b, state=None):
    """Causal depthwise conv. x: (B,S,C); w: (W,C). state: (B,W-1,C) or None.

    Returns (y, new_state) where new_state holds the last W-1 inputs.
    """
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    new_state = xp[:, xp.shape[1] - (width - 1):]
    return jax.nn.silu(y + b), new_state


def _segsum(a):
    """a: (..., L) -> (..., L, L) lower-triangular segment sums:
    out[l, s] = sum_{r=s+1..l} a[r], -inf above diagonal."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, out, -jnp.inf)


def mamba_apply(params, x, cfg, state=None, return_state=False, impl=None):
    """Full-sequence (chunked) Mamba2. x: (B,S,d).

    state: optional dict {conv (B,W-1,C), ssm (B,H,P,N)} to continue from.
    impl: "xla" (default, the einsum chunk math below) or "kernel"
    (kernels/ssd_chunk.py per chunk — skips the (b,H,L,L) ``_segsum``
    materialisation; backward via the reference VJP). Defaults to
    ``cfg.ssd_impl``. Returns (y, new_state | None).
    """
    impl = impl or getattr(cfg, "ssd_impl", "xla")
    if impl not in ("xla", "kernel", "pallas"):
        raise ValueError(f"unknown ssd impl {impl}")
    b, s, d = x.shape
    d_in, nh, p, n = _dims(cfg)
    L = min(cfg.ssm_chunk, s)
    assert s % L == 0, (s, L)
    nc = s // L

    z = jnp.einsum("bsd,de->bse", x, params["in_proj_z"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    xs = jnp.einsum("bsd,de->bse", x, params["in_proj_x"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    bc = jnp.einsum("bsd,de->bse", x, params["in_proj_bc"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    dt = jnp.einsum("bsd,dh->bsh", x, params["in_proj_dt"],
                    preferred_element_type=jnp.float32)

    conv_in = jnp.concatenate([xs, bc], axis=-1)
    conv_state = None if state is None else state["conv"]
    conv_out, new_conv = _conv1d(conv_in, params["conv_w"], params["conv_b"],
                                 conv_state)
    xs, bmat, cmat = jnp.split(conv_out, [d_in, d_in + n], axis=-1)

    dt = jax.nn.softplus(dt + params["dt_bias"])               # (B,S,H) fp32
    a = -jnp.exp(params["a_log"].astype(jnp.float32))          # (H,)
    da = dt * a                                                 # (B,S,H) <=0

    # chunk-major layout; lax.scan over chunks keeps the (L,L) decay matrix
    # transient per-chunk instead of materialised for all chunks at once.
    xh = xs.reshape(b, nc, L, nh, p).astype(jnp.float32)
    bh = bmat.reshape(b, nc, L, n).astype(jnp.float32)
    ch = cmat.reshape(b, nc, L, n).astype(jnp.float32)
    dtc = dt.reshape(b, nc, L, nh)
    dac = da.reshape(b, nc, L, nh)                              # (B,nc,L,H)
    xw = xh * dtc[..., None]                                    # dt-weighted input

    init = (jnp.zeros((b, nh, p, n), jnp.float32) if state is None
            else state["ssm"].astype(jnp.float32))

    @jax.checkpoint
    def chunk_step(h, inputs):
        # checkpointed: the (b,H,L,L) decay matrix is recomputed in backward
        c_i, b_i, x_i, da_i = inputs            # (b,L,n) (b,L,n) (b,L,H,p) (b,L,H)
        if impl in ("kernel", "pallas"):
            # the TPU SSD kernel works per (batch, head) instance: flatten
            # (b, H) -> BH with the single B/C group repeated per head.
            from repro.kernels import ops as kops
            bh = b * nh
            c_k = jnp.repeat(c_i[:, None], nh, 1).reshape(bh, L, n)
            b_k = jnp.repeat(b_i[:, None], nh, 1).reshape(bh, L, n)
            x_k = x_i.transpose(0, 2, 1, 3).reshape(bh, L, p)
            da_k = da_i.transpose(0, 2, 1).reshape(bh, L, 1)
            y_k, h_k = kops.ssd_chunk_trainable(c_k, b_k, x_k, da_k,
                                                h.reshape(bh, p, n))
            return (h_k.reshape(b, nh, p, n),
                    y_k.reshape(b, nh, L, p).transpose(0, 2, 1, 3))
        acs = jnp.cumsum(da_i, axis=1)                          # (b,L,H)
        lmat = jnp.exp(_segsum(da_i.transpose(0, 2, 1)))        # (b,H,L,L)
        y_diag = jnp.einsum("bln,bsn,bhls,bshp->blhp",
                            c_i, b_i, lmat, x_i)
        decay_states = jnp.exp(acs[:, -1:, :] - acs)            # (b,L,H)
        new_state = jnp.einsum("bln,blh,blhp->bhpn",
                               b_i, decay_states, x_i)
        y_off = jnp.einsum("bln,blh,bhpn->blhp", c_i, jnp.exp(acs), h)
        h_new = h * jnp.exp(acs[:, -1, :])[..., None, None] + new_state
        return h_new, y_diag + y_off

    last, ys = jax.lax.scan(
        chunk_step, init,
        (ch.transpose(1, 0, 2, 3), bh.transpose(1, 0, 2, 3),
         xw.transpose(1, 0, 2, 3, 4), dac.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, nh, p)
    y = y + params["d_skip"][None, None, :, None] * xh.reshape(b, s, nh, p)
    y = y.reshape(b, s, d_in).astype(x.dtype)
    y = rmsnorm({"scale": params["norm"]}, y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    if return_state:
        return out, {"conv": new_conv, "ssm": last.astype(jnp.float32)}
    return out, None


def mamba_cache_init(cfg, batch, dtype):
    d_in, nh, p, n = _dims(cfg)
    conv_ch = d_in + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, nh, p, n), jnp.float32),
    }


def mamba_decode(params, x, cache, cfg):
    """Single-token step. x: (B,1,d). Returns (y (B,1,d), new_cache)."""
    b = x.shape[0]
    d_in, nh, p, n = _dims(cfg)

    z = jnp.einsum("bsd,de->bse", x, params["in_proj_z"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    xs = jnp.einsum("bsd,de->bse", x, params["in_proj_x"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    bc = jnp.einsum("bsd,de->bse", x, params["in_proj_bc"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    dt = jnp.einsum("bsd,dh->bsh", x, params["in_proj_dt"],
                    preferred_element_type=jnp.float32)

    conv_in = jnp.concatenate([xs, bc], axis=-1)
    conv_out, new_conv = _conv1d(conv_in, params["conv_w"], params["conv_b"],
                                 cache["conv"])
    xs, bmat, cmat = jnp.split(conv_out, [d_in, d_in + n], axis=-1)

    dt = jax.nn.softplus(dt + params["dt_bias"])[:, 0]          # (B,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    dec = jnp.exp(dt * a)                                        # (B,H)

    xh = xs[:, 0].reshape(b, nh, p).astype(jnp.float32)
    bv = bmat[:, 0].astype(jnp.float32)                          # (B,N)
    cv = cmat[:, 0].astype(jnp.float32)
    h = cache["ssm"] * dec[..., None, None] + jnp.einsum(
        "bhp,bn,bh->bhpn", xh, bv, dt)
    y = jnp.einsum("bhpn,bn->bhp", h, cv)
    y = y + params["d_skip"][None, :, None] * xh
    y = y.reshape(b, 1, d_in).astype(x.dtype)
    y = rmsnorm({"scale": params["norm"]},
                y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, {"conv": new_conv, "ssm": h}
