"""Mixture-of-Experts FFN: token-choice top-k routing with GShard-style
grouped, capacity-based dispatch (one-hot dispatch/combine einsums).

Tokens are split into groups of ``moe_group_size``; each group dispatches
into per-expert capacity buffers of C = factor * g * k / E. This bounds the
dispatch tensors at O(g * E * C) per group (the flat formulation is O(n^2)-ish
and infeasible at 65k tokens/device).

The expert weights carry an explicit ``expert`` logical axis and the expert
intermediates keep an expert dimension, so expert parallelism is a pure
sharding-rule change (XLA inserts the all-to-all at the dispatch einsum).

Aux losses follow Switch/Mixtral: load-balance (mean routed fraction x mean
router prob per expert, scaled by E/k) and router z-loss.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import param

MOE_GROUP_SIZE = 512


class MoEAux(NamedTuple):
    load_balance: jnp.ndarray  # scalar
    z_loss: jnp.ndarray        # scalar
    dropped_frac: jnp.ndarray  # fraction of token-slots dropped by capacity


def moe_init(key, cfg, ffn="moe"):
    del ffn
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": param(ks[0], (d, e), ("embed", "expert"), scale=d ** -0.5),
        "wi": param(ks[1], (e, d, f), ("expert", "embed", "mlp")),
        "wg": param(ks[2], (e, d, f), ("expert", "embed", "mlp")),
        "wo": param(ks[3], (e, f, d), ("expert", "mlp", "embed")),
    }


def _capacity(cfg, group_size):
    c = int(cfg.capacity_factor * group_size * cfg.num_experts_per_tok
            / cfg.num_experts)
    return max(4, -(-c // 4) * 4)  # round up to multiple of 4


def moe_apply(params, x, cfg):
    """x: (B, S, d) -> (out, MoEAux). Token-choice top-k over grouped tokens."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    n = b * s
    gs = min(MOE_GROUP_SIZE, n)
    assert n % gs == 0, (n, gs)
    ng = n // gs
    xt = x.reshape(ng, gs, d)

    logits = jnp.einsum("gnd,de->gne", xt, params["router"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                       # (g, n, e)

    topk_prob, topk_idx = jax.lax.top_k(probs, k)                 # (g, n, k)
    topk_prob = topk_prob / jnp.maximum(
        topk_prob.sum(-1, keepdims=True), 1e-9)

    # --- aux losses (from pre-renormalised probs, global over all tokens) ---
    onehot = jax.nn.one_hot(topk_idx, e, dtype=jnp.float32)       # (g, n, k, e)
    me = probs.mean(axis=(0, 1))                                   # (e,)
    ce = onehot.sum(2).mean(axis=(0, 1))                           # (e,)
    load_balance = e * jnp.sum(me * ce) / k
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # --- per-group capacity dispatch ---
    cap = _capacity(cfg, gs)
    flat = onehot.reshape(ng, gs * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                          # (g, n*k, e)
    pos_in_expert = jnp.sum(pos.reshape(ng, gs, k, e) * onehot, axis=-1)
    keep = (pos_in_expert < cap).astype(jnp.float32)               # (g, n, k)
    dropped = 1.0 - keep.mean()

    # dispatch mask in the model dtype (0/1 exactly representable) and
    # stop_gradient-ed: it is a step function of the routing decision, so
    # its cotangent is identically irrelevant — computing it would
    # materialise (g,n,e,cap) fp32 temporaries in the backward pass.
    # Router gradients flow through ``combine``'s topk_prob factor.
    cap_onehot = jax.nn.one_hot(pos_in_expert, cap, dtype=x.dtype)
    cap_onehot = cap_onehot * keep[..., None].astype(x.dtype)      # (g,n,k,cap)
    dispatch = jax.lax.stop_gradient(
        jnp.einsum("gnke,gnkc->gnec", onehot.astype(x.dtype), cap_onehot))
    # combine folds the slot-k routing weight into the (e, cap) cell the
    # token occupies (NOT dispatch * sum_k p_k — each slot keeps its own p)
    combine = jnp.einsum("gnke,gnkc,gnk->gnec",
                         jax.lax.stop_gradient(onehot.astype(x.dtype)),
                         jax.lax.stop_gradient(cap_onehot),
                         topk_prob.astype(x.dtype))

    xin = jnp.einsum("gnec,gnd->gecd", dispatch, xt,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    h = jnp.einsum("gecd,edf->gecf", xin, params["wi"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    g_ = jnp.einsum("gecd,edf->gecf", xin, params["wg"],
                    preferred_element_type=jnp.float32)
    h = h * jax.nn.silu(g_).astype(x.dtype)
    eo = jnp.einsum("gecf,efd->gecd", h, params["wo"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    out = jnp.einsum("gnec,gecd->gnd", combine.astype(x.dtype), eo,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    aux = MoEAux(load_balance, z_loss, dropped)
    return out.reshape(b, s, d), aux
