"""Feed-forward layers: SwiGLU (llama/qwen), GeGLU (gemma), GELU (musicgen)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import param


def mlp_init(key, cfg, ffn):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if ffn in ("swiglu", "geglu"):
        return {
            "wi": param(ks[0], (d, f), ("embed", "mlp")),
            "wg": param(ks[1], (d, f), ("embed", "mlp")),
            "wo": param(ks[2], (f, d), ("mlp", "embed")),
        }
    if ffn == "gelu":
        return {
            "wi": param(ks[0], (d, f), ("embed", "mlp")),
            "wo": param(ks[2], (f, d), ("mlp", "embed")),
        }
    raise ValueError(ffn)


def mlp_apply(params, x, ffn):
    h = jnp.einsum("...d,df->...f", x, params["wi"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if ffn == "swiglu":
        g = jnp.einsum("...d,df->...f", x, params["wg"],
                       preferred_element_type=jnp.float32)
        h = h * jax.nn.silu(g).astype(x.dtype)
    elif ffn == "geglu":
        g = jnp.einsum("...d,df->...f", x, params["wg"],
                       preferred_element_type=jnp.float32)
        h = h * jax.nn.gelu(g, approximate=True).astype(x.dtype)
    elif ffn == "gelu":
        h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, params["wo"],
                      preferred_element_type=jnp.float32).astype(x.dtype)
