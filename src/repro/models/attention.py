"""Attention mixers: GQA self-attention (full / sliding-window / local),
cross-attention (VLM), with full-sequence, chunked (memory-bounded
online-softmax) and single-token decode paths.

Shape conventions:
  x          (B, S, d)
  q          (B, S, H, hd)      flat head axis (sharding-friendly; see
                                _project_qkv note)
  k, v       (B, S, K, hd)      GQA kv heads; expanded to H for the einsums
  cache k/v  (B, Scap, K, hd)   Scap = seq capacity or sliding window
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.ops import NEG_INF  # shared fp32 mask constant
from repro.models.common import apply_rope, param, softcap


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def attn_init(key, cfg, kind):
    """Params for one attention layer. kind: attn|local_attn|swa_attn|xattn."""
    d, h, k_, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": param(ks[0], (d, h, hd), ("embed", "heads", "head_dim")),
        "wk": param(ks[1], (d, k_, hd), ("embed", "kv_heads", "head_dim")),
        "wv": param(ks[2], (d, k_, hd), ("embed", "kv_heads", "head_dim")),
        "wo": param(ks[3], (h, hd, d), ("heads", "head_dim", "embed"),
                    scale=float(1.0 / np.sqrt(h * hd))),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = param(None, (hd,), ("head_dim",), init="zeros")
        p["k_norm"] = param(None, (hd,), ("head_dim",), init="zeros")
    return p


def _qk_norm(x, scale, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return y.astype(dt)


def _project_qkv(params, cfg, x, kv_src):
    """Returns q (B,S,H,hd), k, v (B,Skv,K,hd).

    NOTE: q keeps the flat H head axis. A (K, G) reshape would make the
    16-way model-axis head sharding inexpressible whenever K < mesh model
    size (GSPMD maps one mesh axis to one tensor dim), silently replicating
    every attention intermediate. Full-sequence attention instead expands
    KV to H heads right before the einsum (_expand_kv) — a few hundred MB
    of transient bf16, fully sharded.
    """
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bsd,dke->bske", kv_src, params["wk"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dke->bske", kv_src, params["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if cfg.use_qk_norm:
        q = _qk_norm(q, params["q_norm"], cfg.norm_eps)
        k = _qk_norm(k, params["k_norm"], cfg.norm_eps)
    return q, k, v


def _expand_kv(k, group):
    """(B,S,K,hd) -> (B,S,K*group,hd); q head h reads kv head h // group."""
    if group == 1:
        return k
    return jnp.repeat(k, group, axis=2)


def _scale(cfg):
    return cfg.attn_scale if cfg.attn_scale is not None else cfg.resolved_head_dim ** -0.5


def _out_proj(params, cfg, o):
    """o: (B,S,H,hd) -> (B,S,d)."""
    return jnp.einsum("bshe,hed->bsd", o, params["wo"],
                      preferred_element_type=jnp.float32).astype(o.dtype)


def _window(cfg, kind):
    if kind in ("local_attn", "swa_attn"):
        return cfg.sliding_window
    return 0  # 0 = unbounded (full causal)


# ---------------------------------------------------------------------------
# full-sequence attention (train / prefill)
# ---------------------------------------------------------------------------

def _attend_dense(q, k, v, q_pos, k_pos, scale, window, cap, causal):
    """Plain (quadratic-memory) attention. q/k/v: (B,S,H,hd) (kv expanded)."""
    s = jnp.einsum("bqhe,bthe->bhqt", q, k,
                   preferred_element_type=jnp.float32) * scale
    if cap:
        s = softcap(s, cap)
    mask = jnp.ones(s.shape[-2:], dtype=bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    o = jnp.einsum("bhqt,bthe->bqhe", p.astype(q.dtype), v,
                   preferred_element_type=jnp.float32).astype(q.dtype)
    return o


def _attend_chunked(q, k, v, q_pos, k_pos, scale, window, cap, causal,
                    chunk, skip):
    """Memory-bounded online-softmax attention.

    Outer ``lax.scan`` over query chunks; inner loop over KV chunks. With
    ``skip=True`` the inner loop is a ``fori_loop`` with data-dependent
    bounds that *skips* fully-masked KV chunks (causal upper triangle /
    outside sliding window) — the beyond-paper compute optimization. With
    ``skip=False`` all KV chunks are visited and masked (fixed trip count:
    FLOPs fully visible to cost_analysis — the accounting baseline).
    """
    b, sq, heads, hd = q.shape
    skv = k.shape[1]
    cq = min(chunk, sq)
    ckv = min(chunk, skv)
    assert sq % cq == 0 and skv % ckv == 0, (sq, skv, chunk)
    nq, nkv = sq // cq, skv // ckv

    from repro.distributed.sharding import constrain_attention
    qc = q.reshape(b, nq, cq, heads, hd).transpose(1, 0, 2, 3, 4)
    # chunk-level constraint: heads->model when divisible, else the
    # WITHIN-chunk query dim (cq) — the nq scan dim must stay unsharded
    qc = constrain_attention(qc, seq_dim=2, head_dim=3, batch_dim=1)
    qpc = q_pos.reshape(nq, cq)
    kc = constrain_attention(k.reshape(b, nkv, ckv, heads, hd),
                             seq_dim=-1, head_dim=3)
    vc = constrain_attention(v.reshape(b, nkv, ckv, heads, hd),
                             seq_dim=-1, head_dim=3)
    kpc = k_pos.reshape(nkv, ckv)

    def kv_step(carry, j, q_i, qp_i):
        m, l, acc = carry
        kj = jax.lax.dynamic_index_in_dim(kc, j, axis=1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vc, j, axis=1, keepdims=False)
        kp = jax.lax.dynamic_index_in_dim(kpc, j, axis=0, keepdims=False)
        s = jnp.einsum("bqhe,bthe->bhqt", q_i, kj,
                       preferred_element_type=jnp.float32) * scale
        if cap:
            s = softcap(s, cap)
        mask = jnp.ones((cq, ckv), dtype=bool)
        if causal:
            mask &= kp[None, :] <= qp_i[:, None]
        if window:
            mask &= qp_i[:, None] - kp[None, :] < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqt,bthe->bhqe", p.astype(q.dtype), vj,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc)

    @jax.checkpoint
    def q_step(_, xs):
        # checkpointed: backward re-runs the inner online-softmax loop
        # instead of storing its per-iteration residuals (flash-style).
        i, q_i, qp_i = xs
        m0 = jnp.full((b, heads, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, heads, cq), jnp.float32)
        a0 = jnp.zeros((b, heads, cq, hd), jnp.float32)
        if skip and causal:
            # last kv chunk overlapping this q chunk (inclusive)
            hi = jnp.minimum((((i + 1) * cq - 1) // ckv) + 1, nkv)
            lo = jnp.maximum((i * cq - (window - 1)) // ckv, 0) if window else 0
            m, l, acc = jax.lax.fori_loop(
                lo, hi, lambda j, c: kv_step(c, j, q_i, qp_i), (m0, l0, a0))
        else:
            (m, l, acc), _ = jax.lax.scan(
                lambda c, j: (kv_step(c, j, q_i, qp_i), None),
                (m0, l0, a0), jnp.arange(nkv))
        l = jnp.maximum(l, 1e-30)
        o = (acc / l[..., None]).astype(q.dtype)  # (b,h,cq,hd)
        return None, o.transpose(0, 2, 1, 3)      # (b,cq,h,hd)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qc, qpc))
    # outs: (nq, b, cq, h, hd) -> (b, sq, h, hd)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, heads, hd)


def _divisor_block(s, want):
    """Largest divisor of ``s`` that is <= ``want`` — the kernel grids
    require the sequence to tile exactly, and CI shapes are not always
    multiples of 128."""
    b = max(min(want, s), 1)
    while s % b:
        b -= 1
    return b


def _attend_flash_kernel(q, k, v, q_pos, k_pos, *, scale, window, cap,
                         chunk, group):
    """Causal attention on the Pallas flash kernel.

    Forward: kernels/flash_attention.py — GQA via the kernel's index maps
    (the unexpanded (B,S,K,hd) k/v go straight in), sliding window and
    softcap inside the kernel. Backward: VJP of the chunked
    online-softmax reference (``_attend_chunked``, skip=True) — Pallas
    TPU kernels are not reverse-mode differentiable, so the backward
    rematerialises flash-style from the saved inputs; the GQA expansion
    happens inside the differentiated reference so dk/dv sum back to K
    heads. Positions are integer primals and get float0 cotangents.
    """
    from repro.kernels import ops as kops
    bq = _divisor_block(q.shape[1], min(chunk, 128))
    bk = _divisor_block(k.shape[1], min(chunk, 128))

    @jax.custom_vjp
    def attend(q, k, v, q_pos, k_pos):
        o = kops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), scale=scale, causal=True,
            window=window, softcap=cap or 0.0, block_q=bq, block_k=bk)
        return o.transpose(0, 2, 1, 3)

    def fwd(q, k, v, q_pos, k_pos):
        return attend(q, k, v, q_pos, k_pos), (q, k, v, q_pos, k_pos)

    def bwd(res, g):
        q, k, v, q_pos, k_pos = res

        def reference(q, k, v):
            # skip=False: the skip variant's data-dependent fori_loop is
            # not reverse-mode differentiable; the fixed-trip-count scan is.
            ke, ve = _expand_kv(k, group), _expand_kv(v, group)
            return _attend_chunked(q, ke, ve, q_pos, k_pos, scale, window,
                                   cap, True, chunk, skip=False)

        dq, dk, dv = jax.vjp(reference, q, k, v)[1](g)
        zero = lambda a: np.zeros(a.shape, jax.dtypes.float0)  # noqa: E731
        return dq, dk, dv, zero(q_pos), zero(k_pos)

    attend.defvjp(fwd, bwd)
    return attend(q, k, v, q_pos, k_pos)


def attn_apply(params, x, *, cfg, kind, positions, kv_src=None,
               impl=None):
    """Full-sequence attention (training / prefill).

    positions: (S,) int32 token positions. kv_src: (B,Sv,d) for xattn.
    Returns (out (B,S,d), kv) — kv returned so prefill can seed caches.
    """
    causal = kind != "xattn"
    src = x if kv_src is None else kv_src
    q, k, v = _project_qkv(params, cfg, x, src)
    if cfg.pos_emb == "rope" and kind != "xattn":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    window = _window(cfg, kind)
    kv_pos = positions if causal else jnp.arange(src.shape[1])
    impl = impl or cfg.attn_impl
    if impl == "auto":
        impl = "xla" if x.shape[1] <= 2048 else "xla_chunked_skip"
    group = cfg.num_heads // cfg.num_kv_heads
    ke, ve = _expand_kv(k, group), _expand_kv(v, group)
    from repro.distributed.sharding import constrain_attention
    q = constrain_attention(q)
    ke = constrain_attention(ke)
    ve = constrain_attention(ve)
    if impl == "xla":
        o = _attend_dense(q, ke, ve, positions, kv_pos, _scale(cfg), window,
                          cfg.attn_logit_softcap, causal)
    elif impl in ("kernel", "pallas") and causal:
        # the TPU flash-attention kernel (kernels/flash_attention.py) with
        # a reference-VJP backward; interpret-mode on CPU ("pallas" is the
        # legacy spelling of "kernel").
        o = _attend_flash_kernel(q, k, v, positions, kv_pos,
                                 scale=_scale(cfg), window=window,
                                 cap=cfg.attn_logit_softcap, group=group,
                                 chunk=cfg.attn_chunk)
    elif impl in ("xla_chunked", "xla_chunked_skip", "kernel", "pallas"):
        # non-causal kernel impl (xattn) falls back to the chunked path
        o = _attend_chunked(q, ke, ve, positions, kv_pos, _scale(cfg), window,
                            cfg.attn_logit_softcap, causal, cfg.attn_chunk,
                            skip=impl == "xla_chunked_skip")
    else:
        raise ValueError(f"unknown attn impl {impl}")
    return _out_proj(params, cfg, o), (k, v)


# ---------------------------------------------------------------------------
# decode (single token, KV cache)
# ---------------------------------------------------------------------------

def attn_cache_init(cfg, kind, batch, seq_len, dtype):
    """Cache arrays for one attention layer.

    Full attention: capacity = seq_len. Windowed: ring buffer of size window.
    xattn: static vision KV of length cfg.vision_seq.
    """
    k_, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if kind == "xattn":
        cap = cfg.vision_seq
    else:
        window = _window(cfg, kind)
        cap = min(seq_len, window) if window else seq_len
    return {
        "k": jnp.zeros((batch, cap, k_, hd), dtype),
        "v": jnp.zeros((batch, cap, k_, hd), dtype),
    }


def attn_decode(params, x, cache, *, cfg, kind, pos, impl=None):
    """One-token decode. x: (B,1,d); pos: scalar int32 (lockstep decode,
    every row at the same position) or (B,) int32 (continuous batching,
    each slot at its own position — rope, cache writes and validity masks
    all become per-row).

    ``impl`` in ("kernel", "pallas") routes the score/softmax/value math
    to kernels/decode_attention.py (xattn keeps the dense path — static
    non-causal vision KV); anything else uses the grouped XLA einsum.
    Returns (out (B,1,d), new_cache).
    """
    group = cfg.num_heads // cfg.num_kv_heads
    if kind == "xattn":
        # static cross-attention against precomputed vision KV
        q, _, _ = _project_qkv(params, cfg, x, x)
        k = _expand_kv(cache["k"], group)
        v = _expand_kv(cache["v"], group)
        kv_pos = jnp.arange(k.shape[1])
        pos_arr = jnp.asarray(pos)
        o = _attend_dense(q, k, v, pos_arr[None], kv_pos, _scale(cfg), 0,
                          cfg.attn_logit_softcap, causal=False)
        return _out_proj(params, cfg, o), cache

    q, k_new, v_new = _project_qkv(params, cfg, x, x)
    pos = jnp.asarray(pos)
    vec = pos.ndim == 1                     # per-row positions
    if cfg.pos_emb == "rope":
        pos_arr = pos[:, None] if vec else pos[None]
        q = apply_rope(q, pos_arr, cfg.rope_theta)
        k_new = apply_rope(k_new, pos_arr, cfg.rope_theta)

    cap = cache["k"].shape[1]
    window = _window(cfg, kind)
    slot = jnp.mod(pos, cap) if window else pos
    if vec:
        upd = lambda c, n, s: jax.lax.dynamic_update_slice_in_dim(  # noqa: E731
            c, n, s, axis=0)
        k = jax.vmap(upd)(cache["k"], k_new, slot)
        v = jax.vmap(upd)(cache["v"], v_new, slot)
    else:
        k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot,
                                                axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot,
                                                axis=1)

    # position held by each cache slot (ring-buffer aware); with per-row
    # pos every quantity gains a leading batch axis
    idx = jnp.arange(cap)
    rpos = pos[:, None] if vec else pos
    if window:
        slot_pos = rpos - jnp.mod(rpos - idx, cap)
    else:
        slot_pos = jnp.broadcast_to(idx, (x.shape[0], cap)) if vec else idx
    valid = (slot_pos >= 0) & (slot_pos <= rpos)
    if window:
        valid &= rpos - slot_pos < window

    impl = impl or cfg.attn_impl
    if impl in ("kernel", "pallas"):
        # the TPU decode-attention kernel: one (B,H,hd) query against the
        # compact (B,K,cap,hd) cache, ring-buffer validity from slot_pos
        # inside the kernel (same semantics as `valid` above).
        from repro.kernels import ops as kops
        o = kops.decode_attention(
            q[:, 0], k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            slot_pos.astype(jnp.int32), pos.astype(jnp.int32),
            scale=_scale(cfg), softcap=cfg.attn_logit_softcap or 0.0,
            window=window, block_k=_divisor_block(cap, 128))
        return _out_proj(params, cfg, o[:, None]), {"k": k, "v": v}

    # grouped GQA einsum directly against the compact (B,S,K,hd) cache:
    # expanding KV to H heads here would read+write `group`x the cache
    # bytes per token — decode is memory-bound, so that multiplies the
    # dominant roofline term (EXPERIMENTS.md §Perf H3). The tiny q is
    # reshaped to (K, G) instead; all big tensors keep the K axis.
    b = q.shape[0]
    hd = q.shape[-1]
    qg = q.reshape(b, 1, cfg.num_kv_heads, group, hd)
    s = jnp.einsum("bqkgh,btkh->bkgqt", qg, k,
                   preferred_element_type=jnp.float32) * _scale(cfg)
    if cfg.attn_logit_softcap:
        s = softcap(s, cfg.attn_logit_softcap)
    vmask = (valid[:, None, None, None, :] if vec
             else valid[None, None, None, None, :])
    s = jnp.where(vmask, s, NEG_INF)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    o = jnp.einsum("bkgqt,btkh->bqkgh", p.astype(q.dtype), v,
                   preferred_element_type=jnp.float32).astype(q.dtype)
    o = o.reshape(b, 1, cfg.num_heads, hd)
    return _out_proj(params, cfg, o), {"k": k, "v": v}


def attn_prefill_cache(cfg, kind, kv, seq_len, dtype):
    """Build a decode cache from prefill KV (k, v each (B,S,K,hd))."""
    k, v = kv
    b = k.shape[0]
    cache = attn_cache_init(cfg, kind, b, seq_len, dtype)
    window = _window(cfg, kind)
    cap = cache["k"].shape[1]
    s = k.shape[1]
    if window and s > cap:
        # keep the last `cap` positions, ring-aligned: slot = pos % cap
        keep_k, keep_v = k[:, s - cap:], v[:, s - cap:]
        pos0 = s - cap
        roll = jnp.mod(pos0, cap)
        keep_k = jnp.roll(keep_k, roll, axis=1)
        keep_v = jnp.roll(keep_v, roll, axis=1)
        return {"k": keep_k.astype(dtype), "v": keep_v.astype(dtype)}
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(dtype), 0, axis=1)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(dtype), 0, axis=1)
    return cache
