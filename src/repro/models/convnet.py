"""Paper-faithful IMPALA agent networks.

``impala_deep``: the IMPALA "deep" ResNet (15 conv layers: 3 sections of
conv + maxpool + 2 residual blocks; FC 256; policy + baseline heads) — the
network TorchBeast trains on Atari (§4, without LSTM).

``minatar_net``: the small ConvNet of the paper's MinAtar adaptation example
(Fig. 2): conv3x3x16 + FC 128 + heads.

Agents are (init, apply) pairs; apply(params, obs) -> AgentOutput. Obs is
(..., H, W, C) float32 (already scaled); leading dims are flattened and
restored so (T, B, ...) learner batches work directly.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import param, split_params


class AgentOutput(NamedTuple):
    policy_logits: jnp.ndarray  # (..., num_actions)
    baseline: jnp.ndarray       # (...,)


class RecurrentAgentOutput(NamedTuple):
    policy_logits: jnp.ndarray
    baseline: jnp.ndarray
    core_state: tuple           # (h, c) LSTM state, threaded by the actor


def _conv_init(key, kh, kw, cin, cout):
    scale = 1.0 / np.sqrt(kh * kw * cin)
    return {
        "w": param(key, (kh, kw, cin, cout),
                   ("conv_h", "conv_w", "conv_in", "conv_out"), scale=scale),
        "b": param(None, (cout,), ("conv_out",), init="zeros"),
    }


def _conv(p, x, stride=1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _linear_init(key, din, dout, scale=None):
    return {
        "w": param(key, (din, dout), ("fc_in", "fc_out"), scale=scale),
        "b": param(None, (dout,), ("fc_out",), init="zeros"),
    }


def _linear(p, x):
    return x @ p["w"] + p["b"]


def _maxpool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1),
        [(0, 0), (1, 1), (1, 1), (0, 0)])


# ---------------------------------------------------------------------------
# IMPALA deep ResNet
# ---------------------------------------------------------------------------

def impala_deep(obs_shape, num_actions, channels=(16, 32, 32), fc=256):
    h, w, c_in = obs_shape

    def init(key):
        p = {"sections": []}
        cin = c_in
        sh, sw = h, w
        for ch in channels:
            key, k1, k2, k3, k4, k5 = jax.random.split(key, 6)
            p["sections"].append({
                "conv": _conv_init(k1, 3, 3, cin, ch),
                "res": [
                    {"c1": _conv_init(k2, 3, 3, ch, ch),
                     "c2": _conv_init(k3, 3, 3, ch, ch)},
                    {"c1": _conv_init(k4, 3, 3, ch, ch),
                     "c2": _conv_init(k5, 3, 3, ch, ch)},
                ],
            })
            cin = ch
            sh, sw = -(-sh // 2), -(-sw // 2)
        flat = sh * sw * channels[-1]
        key, k1, k2, k3 = jax.random.split(key, 4)
        p["fc"] = _linear_init(k1, flat, fc)
        p["policy"] = _linear_init(k2, fc, num_actions, scale=0.01)
        p["baseline"] = _linear_init(k3, fc, 1, scale=0.01)
        return p

    def apply(params, obs):
        lead = obs.shape[:-3]
        x = obs.reshape((-1,) + obs.shape[-3:]).astype(jnp.float32)
        for sec in params["sections"]:
            x = _conv(sec["conv"], x)
            x = _maxpool(x)
            for res in sec["res"]:
                y = _conv(res["c1"], jax.nn.relu(x))
                y = _conv(res["c2"], jax.nn.relu(y))
                x = x + y
        x = jax.nn.relu(x).reshape(x.shape[0], -1)
        x = jax.nn.relu(_linear(params["fc"], x))
        logits = _linear(params["policy"], x)
        baseline = _linear(params["baseline"], x)[..., 0]
        return AgentOutput(logits.reshape(lead + (num_actions,)),
                           baseline.reshape(lead))

    return init, apply


# ---------------------------------------------------------------------------
# MinAtar net (paper Fig. 2)
# ---------------------------------------------------------------------------

def minatar_net(obs_shape, num_actions, conv_ch=16, fc=128):
    h, w, c_in = obs_shape

    def init(key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        flat = (h - 2) * (w - 2) * conv_ch
        return {
            "conv": _conv_init(k1, 3, 3, c_in, conv_ch),
            "core": _linear_init(k2, flat, fc),
            "policy": _linear_init(k3, fc, num_actions, scale=0.01),
            "baseline": _linear_init(k4, fc, 1, scale=0.01),
        }

    def apply(params, obs):
        lead = obs.shape[:-3]
        x = obs.reshape((-1,) + obs.shape[-3:]).astype(jnp.float32)
        y = jax.lax.conv_general_dilated(
            x, params["conv"]["w"], (1, 1), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + params["conv"]["b"]
        y = jax.nn.relu(y).reshape(y.shape[0], -1)
        y = jax.nn.relu(_linear(params["core"], y))
        logits = _linear(params["policy"], y)
        baseline = _linear(params["baseline"], y)[..., 0]
        return AgentOutput(logits.reshape(lead + (num_actions,)),
                           baseline.reshape(lead))

    return init, apply


# ---------------------------------------------------------------------------
# recurrent agent: ConvNet torso + LSTM core (TorchBeast's core_state API)
# ---------------------------------------------------------------------------

def minatar_lstm_net(obs_shape, num_actions, conv_ch=16, core=128):
    """MinAtar ConvNet torso + LSTM core. apply(params, obs, core_state,
    done) -> RecurrentAgentOutput; obs is a single step (B, H, W, C) — the
    rollout threads core_state exactly like TorchBeast's agent interface,
    resetting it where done=True."""
    h, w, c_in = obs_shape

    def init(key):
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        flat = (h - 2) * (w - 2) * conv_ch
        return {
            "conv": _conv_init(k1, 3, 3, c_in, conv_ch),
            "torso": _linear_init(k2, flat, core),
            "lstm_x": _linear_init(k5, core, 4 * core,
                                   scale=core ** -0.5),
            "lstm_h": _linear_init(jax.random.fold_in(k5, 1), core,
                                   4 * core, scale=core ** -0.5),
            "policy": _linear_init(k3, core, num_actions, scale=0.01),
            "baseline": _linear_init(k4, core, 1, scale=0.01),
        }

    def initial_state(batch):
        z = jnp.zeros((batch, core), jnp.float32)
        return (z, z)

    def apply(params, obs, core_state, done=None):
        x = obs.astype(jnp.float32)
        y = jax.lax.conv_general_dilated(
            x, params["conv"]["w"], (1, 1), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + params["conv"]["b"]
        y = jax.nn.relu(y).reshape(y.shape[0], -1)
        y = jax.nn.relu(_linear(params["torso"], y))
        hs, cs = core_state
        if done is not None:  # TorchBeast: zero the state at episode ends
            keep = (~done)[:, None].astype(hs.dtype)
            hs, cs = hs * keep, cs * keep
        gates = _linear(params["lstm_x"], y) + _linear(params["lstm_h"], hs)
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        cs = jax.nn.sigmoid(f + 1.0) * cs + jax.nn.sigmoid(i) * jnp.tanh(g)
        hs = jax.nn.sigmoid(o) * jnp.tanh(cs)
        logits = _linear(params["policy"], hs)
        baseline = _linear(params["baseline"], hs)[..., 0]
        return RecurrentAgentOutput(logits, baseline, (hs, cs))

    return init, apply, initial_state


def init_agent(init_fn, key):
    """Split an agent's AxisParam tree into (values, axes)."""
    return split_params(init_fn(key))
