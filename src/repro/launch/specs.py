"""Program + input-spec builders for the dry-run and launchers.

For every (arch, input-shape) pair this module builds:
  * the step function to lower (train_step / prefill_step / serve_step),
  * ShapeDtypeStruct stand-ins for every input, with NamedShardings attached
    (weak-type-correct, shardable, zero allocation),
so ``jax.jit(step).lower(**specs).compile()`` proves the distribution
config end-to-end (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import (INPUT_SHAPES, ImplContext, InputShape,
                                ModelConfig, TrainConfig)
from repro.core import learner as learner_lib
from repro.distributed import sharding as shd
from repro.models import model as model_lib
from repro.models.common import split_params
from repro.optim import make_optimizer

# archs whose exact config is pure full attention: long_500k runs only with
# the flag-gated sliding-window serving variant (DESIGN.md §5).
LONG_CONTEXT_OVERRIDE = {
    "qwen3-32b", "qwen3-4b", "deepseek-coder-33b", "musicgen-large",
    "llama-3.2-vision-90b",
}


def _shape(shape) :
    return shape if isinstance(shape, InputShape) else INPUT_SHAPES[shape]


def resolve_config(arch: str, shape_name, base_cfg=None,
                   impls: ImplContext | None = None) -> ModelConfig:
    """Arch config, specialised to the input shape where required.
    ``shape_name`` may be a name or an InputShape; ``base_cfg`` overrides the
    registry lookup (reduced-config integration tests). ``impls`` is the
    CLI-resolved kernel-impl context (dryrun --attn-impl/--ssd-impl);
    the default stays the memory-bounded chunked path."""
    shape = _shape(shape_name)
    shape_name = shape.name
    cfg = base_cfg if base_cfg is not None else get_config(arch)
    if shape_name == "long_500k" and arch in LONG_CONTEXT_OVERRIDE:
        pattern = tuple(("swa_attn" if m == "attn" else m, f)
                        for m, f in cfg.block_pattern)
        cfg = dataclasses.replace(cfg, block_pattern=pattern,
                                  sliding_window=cfg.long_context_window)
    kind = shape.kind
    if kind == "train":
        # bound the (b,H,L,L) SSD decay-matrix recompute in backward
        cfg = dataclasses.replace(cfg, ssm_chunk=min(cfg.ssm_chunk, 128))
    # memory-bounded chunked online-softmax attention everywhere: the
    # backward pass re-runs each query-chunk's inner loop (q_step is
    # checkpointed), so no (S,S) scores or per-iteration softmax residuals
    # are ever resident. FLOPs hidden inside the chunk loops are restored
    # by roofline.inner_scan_corrections.
    impls = impls or ImplContext()
    cfg = dataclasses.replace(cfg, attn_impl=impls.attn or "xla_chunked")
    if impls.ssd:
        cfg = dataclasses.replace(cfg, ssd_impl=impls.ssd)
    return cfg


def abstract_params(cfg: ModelConfig, mesh, rules):
    """(param ShapeDtypeStructs with shardings, axes tree)."""
    box = {}

    def f():
        vals, axes = split_params(
            model_lib.model_init(jax.random.PRNGKey(0), cfg))
        box["axes"] = axes  # strings: captured at trace time, not returned
        return vals

    shapes = jax.eval_shape(f)
    axes = box["axes"]
    shardings = shd.param_shardings(axes, mesh, rules, shapes)
    specs = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)
    return specs, axes


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _batch_spec(mesh, batch: int):
    """Shard the batch dim over all data-like axes when divisible."""
    axes = shd.data_axes(mesh)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if axes and batch % size == 0:
        return axes if len(axes) > 1 else axes[0]
    return None


def cache_specs(cfg: ModelConfig, mesh, batch: int, seq_len: int):
    """ShapeDtypeStructs for the decode cache with heuristic shardings:
    leading groups axis replicated; batch dim over data axes; then the
    largest remaining dim sharded over 'model' when divisible."""
    cache_shapes = jax.eval_shape(
        lambda: model_lib.cache_init(cfg, batch, seq_len))
    bspec = _batch_spec(mesh, batch)
    msize = mesh.shape["model"]

    def one(leaf):
        parts = [None] * len(leaf.shape)
        if len(leaf.shape) >= 2:
            parts[1] = bspec if leaf.shape[1] == batch else None
        cands = sorted(range(2, len(leaf.shape)),
                       key=lambda i: -leaf.shape[i])
        for i in cands:
            if leaf.shape[i] % msize == 0:
                parts[i] = "model"
                break
        while parts and parts[-1] is None:
            parts.pop()
        return jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype,
            sharding=NamedSharding(mesh, P(*parts)))

    return jax.tree.map(one, cache_shapes)


# ---------------------------------------------------------------------------
# program builders
# ---------------------------------------------------------------------------

def build_train(arch: str, shape_name, mesh, rules,
                train_cfg: TrainConfig | None = None, base_cfg=None,
                impls=None):
    """IMPALA LM learner step + input specs for a train shape."""
    cfg = resolve_config(arch, shape_name, base_cfg, impls)
    ishape = _shape(shape_name)
    train_cfg = train_cfg or TrainConfig()
    opt = make_optimizer(train_cfg)

    params, axes = abstract_params(cfg, mesh, rules)
    opt_shapes = jax.eval_shape(opt.init, params)
    # ZeRO-1: optimizer state also sharded over the data axes
    opt_shardings = {k: shd.zero1_shardings(axes, opt_shapes[k], mesh, rules)
                     for k in opt_shapes}
    opt_state = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        opt_shapes, opt_shardings)

    # ZeRO-2: constrain gradients to the (param-sharding + data-axis) layout
    # of the optimizer state, so the gradient reduction lowers to a
    # reduce-scatter and all fp32 elementwise temporaries stay sharded.
    grad_shardings = shd.zero1_shardings(
        axes, jax.tree.map(lambda x: x, params), mesh, rules)

    def grad_constraint(grads):
        return jax.tree.map(jax.lax.with_sharding_constraint, grads,
                            grad_shardings)

    step_fn = learner_lib.make_lm_train_step(
        cfg, opt, train_cfg, grad_constraint=grad_constraint)

    b, s = ishape.global_batch, ishape.seq_len
    bspec = _batch_spec(mesh, b)
    batch = {
        "tokens": _sds((b, s + 1), jnp.int32, mesh, P(bspec, None)),
        "behavior_logprob": _sds((b, s), jnp.float32, mesh, P(bspec, None)),
        "reward": _sds((b, s), jnp.float32, mesh, P(bspec, None)),
        "done": _sds((b, s), jnp.bool_, mesh, P(bspec, None)),
    }
    if cfg.vision_seq:
        batch["vision"] = _sds((b, cfg.vision_seq, cfg.d_model),
                               jnp.dtype(cfg.dtype), mesh,
                               P(bspec, None, None))
    step = _sds((), jnp.int32, mesh, P())

    def wrapped(params, opt_state, step, batch):
        with shd.use_rules(mesh, rules):
            return step_fn(params, opt_state, step, batch)

    scalar = NamedSharding(mesh, P())
    out_shardings = (
        jax.tree.map(lambda x: x.sharding, params),
        jax.tree.map(lambda x: x.sharding, opt_state),
        jax.tree.map(lambda _: scalar,
                     {"loss": 0, "pg_loss": 0, "baseline_loss": 0,
                      "entropy_loss": 0, "reward_per_step": 0}),
    )
    jit_kwargs = {"donate_argnums": (0, 1), "out_shardings": out_shardings}
    return wrapped, (params, opt_state, step, batch), cfg, jit_kwargs


def build_prefill(arch: str, shape_name, mesh, rules, base_cfg=None,
                  impls=None):
    cfg = resolve_config(arch, shape_name, base_cfg, impls)
    ishape = _shape(shape_name)
    b, s = ishape.global_batch, ishape.seq_len
    params, _ = abstract_params(cfg, mesh, rules)
    bspec = _batch_spec(mesh, b)
    tokens = _sds((b, s), jnp.int32, mesh, P(bspec, None))
    args = [params, tokens]
    if cfg.vision_seq:
        args.append(_sds((b, cfg.vision_seq, cfg.d_model),
                         jnp.dtype(cfg.dtype), mesh, P(bspec, None, None)))

    cache_out = cache_specs(cfg, mesh, b, s)

    def prefill_step(params, tokens, vision=None):
        with shd.use_rules(mesh, rules):
            hidden, aux, cache = model_lib.prefill(
                params, tokens, cfg=cfg, vision=vision, cache_seq_len=s)
            logits = model_lib.logits_from_hidden(params, cfg,
                                                  hidden[:, -1:])
        return logits, cache

    out_shardings = (
        NamedSharding(mesh, P(bspec, None, None)),
        jax.tree.map(lambda x: x.sharding, cache_out),
    )
    return prefill_step, tuple(args), cfg, {"out_shardings": out_shardings}


def build_decode(arch: str, shape_name, mesh, rules, base_cfg=None,
                 impls=None):
    cfg = resolve_config(arch, shape_name, base_cfg, impls)
    ishape = _shape(shape_name)
    b, s = ishape.global_batch, ishape.seq_len
    params, _ = abstract_params(cfg, mesh, rules)
    bspec = _batch_spec(mesh, b)
    tokens = _sds((b, 1), jnp.int32, mesh, P(bspec, None))
    cache = cache_specs(cfg, mesh, b, s)
    pos = _sds((), jnp.int32, mesh, P())

    def serve_step(params, tokens, cache, pos):
        with shd.use_rules(mesh, rules):
            # unroll: per-layer in-place cache writes on the donated buffer
            # (a scan would double-buffer the cache); also makes all layers
            # visible to cost_analysis (no while loop).
            return model_lib.serve_step(params, tokens, cache, pos, cfg=cfg,
                                        unroll=True)

    out_shardings = (
        NamedSharding(mesh, P(bspec, None, None)),       # logits
        NamedSharding(mesh, P(bspec, None)),             # baseline
        jax.tree.map(lambda x: x.sharding, cache),       # new cache
    )
    jit_kwargs = {"donate_argnums": (2,), "out_shardings": out_shardings}
    return serve_step, (params, tokens, cache, pos), cfg, jit_kwargs


def build_program(arch: str, shape_name, mesh, rules, base_cfg=None,
                  impls=None):
    kind = _shape(shape_name).kind
    if kind == "train":
        return build_train(arch, shape_name, mesh, rules, base_cfg=base_cfg,
                           impls=impls)
    if kind == "prefill":
        return build_prefill(arch, shape_name, mesh, rules,
                             base_cfg=base_cfg, impls=impls)
    return build_decode(arch, shape_name, mesh, rules, base_cfg=base_cfg,
                        impls=impls)
