"""End-to-end training driver: thin config -> Runtime assembly.

Every mode builds (RolloutSource, step_fn) and hands them to the unified
``core.runtime.Runtime`` — there is no per-mode step loop here.

Modes:
  rl-agent  — paper-faithful IMPALA: on-device rollouts (catch/gridworld
              envs) + convnet agent + V-trace learner, double-buffered by
              default (``--sync`` to disable, ``--actors host`` for the
              MonoBeast/PolyBeast host-loop actor architecture).
  lm-rl     — IMPALA with an LLM policy on the token-MDP: the decode path
              generates episodes (behavior log-probs recorded), the learner
              applies V-trace (DESIGN.md §2).
  lm        — plain next-token pretraining on the synthetic corpus.

Meshes: rl-agent shards over a 1-D ("data",) mesh (--mesh-data); the LM
paths shard over a 2-D ("data","model") mesh (--mesh-data x --mesh-model,
MEGATRON_RULES: params over "model", token batch over "data") and run
multi-host via --coordinator/--num-processes/--process-id (the
jax.distributed bootstrap of launch/multihost.py — the mesh is built from
the GLOBAL device set, so the same entry point runs single-host CPU CI
and a real pod slice).

Examples:
  PYTHONPATH=src python -m repro.launch.train --mode rl-agent --env catch \
      --steps 500
  PYTHONPATH=src python -m repro.launch.train --mode rl-agent --actors host \
      --steps 50
  PYTHONPATH=src python -m repro.launch.train --mode rl-agent --env catch \
      --replay elite --replay-ratio 1.0 --steps 500
  PYTHONPATH=src python -m repro.launch.train --mode lm-rl \
      --arch granite-moe-1b-a400m --reduced --steps 50
  PYTHONPATH=src python -m repro.launch.train --mode lm --arch qwen3-4b \
      --reduced --steps 100 --checkpoint-dir /tmp/ckpt
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.train --mode lm-rl --arch qwen3-4b --reduced \
      --steps 50 --mesh-data 2 --mesh-model 2
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced_config
from repro.configs.atari_impala import small_train
from repro.configs.base import ImplContext, TrainConfig
from repro.core import learner as learner_lib
from repro.core import sources as sources_lib
from repro.core.runtime import Runtime
from repro.models import model as model_lib
from repro.models.convnet import impala_deep, init_agent, minatar_net
from repro.optim import make_optimizer


def build_rl_agent(args):
    import dataclasses

    from repro.envs import catch, gridworld
    env = {"catch": catch, "gridworld": gridworld}[args.env].make()
    train_cfg = small_train(total_steps=args.steps,
                            learning_rate=args.lr or 2e-3,
                            batch_size=args.batch or 32)
    if args.replay != "off":
        train_cfg = dataclasses.replace(train_cfg, clear_policy_cost=0.01,
                                        clear_value_cost=0.005)
    net = impala_deep if args.agent == "deep" else minatar_net
    init_fn, apply_fn = net(env.obs_shape, env.num_actions)
    params, _ = init_agent(init_fn, jax.random.PRNGKey(train_cfg.seed))
    opt = make_optimizer(train_cfg)

    # The source composition matrix: (device | sharded | host) actors,
    # optionally wrapped in replay — every combination with --mesh-data
    # composes (per-device-sliced replay, mesh-split host learner queue).
    mesh = None
    if args.mesh_data:
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(args.mesh_data)

    if args.actors == "host":
        source = sources_lib.HostLoopSource(
            env, apply_fn, num_actors=train_cfg.num_actors,
            unroll_length=train_cfg.unroll_length,
            batch_size=train_cfg.batch_size, seed=train_cfg.seed,
            mesh=mesh)
    elif mesh is not None:
        source = sources_lib.ShardedDeviceSource.for_env(
            env, apply_fn, unroll_length=train_cfg.unroll_length,
            batch_size=train_cfg.batch_size,
            key=jax.random.PRNGKey(train_cfg.seed + 1),
            mesh=mesh, pipelined=not args.sync)
    else:
        source = sources_lib.DeviceSource.for_env(
            env, apply_fn, unroll_length=train_cfg.unroll_length,
            batch_size=train_cfg.batch_size,
            key=jax.random.PRNGKey(train_cfg.seed + 1),
            pipelined=not args.sync)
    if args.replay != "off":
        from repro.core import replay as replay_lib
        if mesh is not None:
            buffer = replay_lib.ShardedReplay(args.replay,
                                              args.replay_capacity, mesh)
        else:
            buffer = replay_lib.make_buffer(args.replay,
                                            args.replay_capacity)
        source = sources_lib.ReplaySource(
            source, buffer,
            replay_ratio=args.replay_ratio, seed=train_cfg.seed,
            value_fn=jax.jit(lambda p, obs: apply_fn(p, obs).baseline))
    step_fn = jax.jit(learner_lib.make_train_step(
        apply_fn, opt, train_cfg, mesh=mesh,
        vtrace_impl=args.vtrace_impl))
    extras = {"log_keys": ("reward_per_step", "loss")}
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec
        # learner state lives replicated on the mesh; the source reads
        # per-device shard views of it with zero copies.
        placement = lambda tree: jax.device_put(  # noqa: E731
            tree, NamedSharding(mesh, PartitionSpec()))
        params = placement(params)
        extras["placement"] = placement
    return source, step_fn, params, opt.init(params), extras


def _lm_mesh_setup(args, params, axes):
    """2-D ("data","model") mesh context for the LM paths: place the
    params per MEGATRON_RULES (model-sharded where divisible; the token
    batch shards over "data" inside the learner step) and build the
    grad-constraint hook pinning gradients to the same layout. Returns
    (mesh, rules, placed_params, grad_constraint) — (None, None, params,
    None) when neither --mesh-data nor --mesh-model is set, which
    compiles to the exact pre-mesh program."""
    if not (args.mesh_data or args.mesh_model):
        return None, None, params, None
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_mesh2d
    mesh = make_mesh2d(args.mesh_data or 1, args.mesh_model or 1)
    rules = shd.MEGATRON_RULES
    pshard = shd.param_shardings(axes, mesh, rules, params)
    params = jax.device_put(params, pshard)
    grad_constraint = lambda grads: jax.tree.map(  # noqa: E731
        jax.lax.with_sharding_constraint, grads, pshard)
    return mesh, rules, params, grad_constraint


def _restore_shardings(params, opt_state):
    """extras entry telling --resume to reassemble each restored leaf onto
    the LIVE mesh layout (checkpoint.restore ``shardings=``). Because the
    shardings come from the freshly-initialised state — not the
    checkpoint — this is also the elastic-resume path: a checkpoint from
    mesh (2,2) restores onto (4,1) by re-slicing the saved shards."""
    from repro.distributed.sharding import tree_shardings
    return tree_shardings({"params": params, "opt_state": opt_state})


def _apply_impls(cfg, args):
    """Fold --attn-impl / --ssd-impl into the model config (the single
    ImplContext every downstream path reads, mirroring --vtrace-impl)."""
    return ImplContext.from_args(args).apply(cfg)


def build_lm_rl(args):
    cfg = _apply_impls(
        (get_reduced_config if args.reduced else get_config)(args.arch), args)
    train_cfg = TrainConfig(optimizer="adamw", learning_rate=args.lr or 3e-4,
                            grad_clip=1.0, total_steps=args.steps,
                            lr_schedule="constant", entropy_cost=0.003)
    params, axes = model_lib.init(jax.random.PRNGKey(train_cfg.seed), cfg)
    opt = make_optimizer(train_cfg)
    mesh, rules, params, grad_constraint = _lm_mesh_setup(args, params, axes)
    opt_state = opt.init(params)   # zeros_like inherits the param shardings
    source = sources_lib.GeneratorSource(
        cfg, batch_size=args.batch or 16, episode_length=args.seq,
        key=jax.random.PRNGKey(7), mesh=mesh, rules=rules)
    step_fn = jax.jit(sources_lib.lm_rl_step_from_rollout(
        learner_lib.make_lm_train_step(cfg, opt, train_cfg,
                                       loss_chunk=args.seq,
                                       vtrace_impl=args.vtrace_impl,
                                       grad_constraint=grad_constraint,
                                       mesh=mesh, rules=rules)))
    extras = {"log_keys": ("reward_per_step", "pg_loss", "entropy_loss")}
    if mesh is not None:
        extras["restore_shardings"] = _restore_shardings(params, opt_state)
    return source, step_fn, params, opt_state, extras


def build_lm(args):
    from repro.data import PackedBatchIterator, markov_corpus
    cfg = _apply_impls(
        (get_reduced_config if args.reduced else get_config)(args.arch), args)
    train_cfg = TrainConfig(optimizer="adamw", learning_rate=args.lr or 3e-4,
                            grad_clip=1.0, total_steps=args.steps,
                            lr_schedule="cosine", warmup_steps=10)
    params, axes = model_lib.init(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer(train_cfg)
    mesh, rules, params, grad_constraint = _lm_mesh_setup(args, params, axes)
    opt_state = opt.init(params)
    step_fn = jax.jit(learner_lib.make_lm_pretrain_step(
        cfg, opt, loss_chunk=min(512, args.seq),
        grad_constraint=grad_constraint, mesh=mesh, rules=rules))

    b = args.batch or 16
    corpus = markov_corpus(cfg.vocab_size, 200_000, seed=1)
    # Checkpointable iterator (seed + offset): its state rides in every
    # checkpoint through DataSource.state_dict, so --resume replays the
    # exact batch sequence (bit-identical to an uninterrupted run).
    it = PackedBatchIterator(corpus, b, args.seq, seed=train_cfg.seed)
    vision = None
    if cfg.vision_seq:
        vision = jnp.zeros((b, cfg.vision_seq, cfg.d_model),
                           jnp.dtype(cfg.dtype))
    put = jnp.asarray
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.distributed.sharding import batch_axes_spec
        put = lambda v: jax.device_put(v, NamedSharding(  # noqa: E731
            mesh, batch_axes_spec(mesh, rules, v.ndim, v.shape, 0)
            or PartitionSpec()))

    def transform(batch):
        batch = {k: put(v) for k, v in batch.items()}
        if vision is not None:
            batch["vision"] = vision
        return batch

    source = sources_lib.DataSource(it, frames_per_batch=b * args.seq,
                                    transform=transform, close=it.close)
    extras = {"log_keys": ("loss",), "fps_label": "tok/s"}
    if mesh is not None:
        extras["restore_shardings"] = _restore_shardings(params, opt_state)
    return source, step_fn, params, opt_state, extras


_BUILDERS = {"rl-agent": build_rl_agent, "lm-rl": build_lm_rl,
             "lm": build_lm}


def _checkpoint_meta(args):
    """Config identity recorded in every checkpoint manifest and validated
    on --resume: restoring an lm checkpoint into an rl-agent run (or a
    different arch/env) must fail loudly up front, naming the mismatched
    keys — not die deep in tree-structure assembly."""
    meta = {"mode": args.mode}
    if args.mode == "rl-agent":
        meta["env"] = args.env
    else:
        meta["arch"] = args.arch
    return meta


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--mode", choices=sorted(_BUILDERS), default="rl-agent")
    p.add_argument("--env", choices=["catch", "gridworld"], default="catch")
    p.add_argument("--agent", choices=["minatar", "deep"], default="minatar")
    p.add_argument("--actors", choices=["device", "host"], default="device",
                   help="rl-agent only: compiled on-device rollouts or the "
                        "MonoBeast host actor loop")
    p.add_argument("--sync", action="store_true",
                   help="disable double-buffered rollout dispatch")
    p.add_argument("--mesh-data", type=int, default=None, metavar="N",
                   help="data-parallel axis size: rl-agent shards batch + "
                        "source over a 1-D ('data',) mesh "
                        "(ShardedDeviceSource + sharded train step); "
                        "lm/lm-rl use it as the 'data' axis of the 2-D "
                        "('data','model') mesh (on CPU set XLA_FLAGS="
                        "--xla_force_host_platform_device_count=N)")
    p.add_argument("--mesh-model", type=int, default=None, metavar="M",
                   help="lm/lm-rl only: model-parallel axis size of the "
                        "2-D ('data','model') mesh — MEGATRON_RULES shard "
                        "params/activations over 'model' and the token "
                        "batch over 'data'; composes with --mesh-data "
                        "and --resume")
    p.add_argument("--coordinator", default=None, metavar="HOST:PORT",
                   help="multi-host: address of process 0 "
                        "(jax.distributed bootstrap, launch/multihost.py); "
                        "the mesh is then built from the GLOBAL device set")
    p.add_argument("--num-processes", type=int, default=1,
                   help="multi-host: total process count")
    p.add_argument("--process-id", type=int, default=0,
                   help="multi-host: this process's index")
    p.add_argument("--vtrace-impl", choices=["scan", "kernel"],
                   default="scan",
                   help="rl-agent/lm-rl: V-trace recursion — reverse-scan "
                        "reference or the Pallas TPU kernel "
                        "(interpret-mode on CPU); ignored by --mode lm")
    p.add_argument("--attn-impl", default=None,
                   choices=["xla", "xla_chunked", "xla_chunked_skip",
                            "kernel"],
                   help="lm/lm-rl: attention impl on every hot path — "
                        "'kernel' selects the Pallas flash-attention "
                        "kernel for train/prefill and the decode-attention "
                        "kernel for generation (interpret-mode on CPU); "
                        "default: the config's attn_impl ('auto')")
    p.add_argument("--ssd-impl", default=None, choices=["xla", "kernel"],
                   help="lm/lm-rl: Mamba2 chunked-scan impl — 'kernel' "
                        "routes each SSD chunk to the Pallas kernel "
                        "(skips the (L,L) decay-matrix materialisation); "
                        "default: the config's ssd_impl ('xla')")
    p.add_argument("--resume", action="store_true",
                   help="restore {params, opt_state, step} AND the rollout "
                        "source state (env carries, RNG streams, replay "
                        "contents) from the latest checkpoint in "
                        "--checkpoint-dir and continue from the saved step "
                        "— bit-identical to an uninterrupted run for the "
                        "on-device actor paths")
    p.add_argument("--checkpoint-every", type=int, default=0, metavar="N",
                   help="also checkpoint every N steps (0: final/crash "
                        "checkpoints only) — the kill/--resume safety net")
    p.add_argument("--replay", default="off",
                   choices=["off", "uniform", "elite", "attentive"],
                   help="rl-agent only: mix replayed rollouts into every "
                        "learner batch (core/replay.py)")
    p.add_argument("--replay-capacity", type=int, default=512,
                   help="replay buffer size in rollouts")
    p.add_argument("--replay-ratio", type=float, default=1.0,
                   help="replayed:fresh columns per batch (1.0 = 1:1)")
    p.add_argument("--arch", default="qwen3-4b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=None)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=None)
    p.add_argument("--checkpoint-dir", default=None)
    args = p.parse_args(argv)
    if args.mesh_model and args.mode == "rl-agent":
        p.error("--mesh-model applies to the LM paths (--mode lm/lm-rl); "
                "rl-agent is data-parallel only (--mesh-data)")
    if args.num_processes > 1 and not args.coordinator:
        # without the bootstrap each process would train a full
        # independent model and clobber the shared checkpoint dir
        p.error("--num-processes > 1 requires --coordinator")
    if args.coordinator:
        # must run before the builders query devices: the mesh factories
        # read jax.devices(), which is global only after the bootstrap.
        from repro.launch.multihost import bootstrap
        bootstrap(args.coordinator, args.num_processes, args.process_id)

    source, step_fn, params, opt_state, extras = _BUILDERS[args.mode](args)
    placement = extras.pop("placement", None)
    restore_shardings = extras.pop("restore_shardings", None)
    start_step = 0
    if args.resume:
        if not args.checkpoint_dir:
            p.error("--resume requires --checkpoint-dir")
        from repro import checkpoint as ckpt_lib
        path = ckpt_lib.latest_step_path(args.checkpoint_dir)
        if path is None:
            print(f"--resume: no checkpoint under {args.checkpoint_dir}, "
                  "starting fresh")
        else:
            # Cheap pre-flight: the manifest's recorded config identity
            # must match this run before any shard is read.
            saved_meta = ckpt_lib.read_metadata(path)
            want = _checkpoint_meta(args)
            bad = sorted(k for k in want
                         if k in saved_meta and saved_meta[k] != want[k])
            if bad:
                detail = ", ".join(
                    f"{k}: checkpoint={saved_meta[k]!r} run={want[k]!r}"
                    for k in bad)
                raise SystemExit(
                    f"--resume: checkpoint {path} was written by a "
                    f"different configuration ({detail})")
            # sharded-aware restore: with restore_shardings each leaf is
            # reassembled straight onto its live mesh sharding
            # (model-sharded params land distributed, no replicated host
            # tree) — including elastic resume onto a different mesh.
            # Same-mesh, prefer the SAVED specs (bit-exact resume: the
            # resumed step then compiles the exact steady-state program).
            if restore_shardings is not None:
                restore_shardings = (ckpt_lib.saved_shardings(
                    path, restore_shardings) or restore_shardings)
            restored, meta = ckpt_lib.restore(
                path, {"params": params, "opt_state": opt_state},
                shardings=restore_shardings)
            if restore_shardings is not None:
                params = restored["params"]
                opt_state = restored["opt_state"]
            else:
                place = placement or (
                    lambda tree: jax.tree.map(jnp.asarray, tree))
                params = place(restored["params"])
                opt_state = place(restored["opt_state"])
            start_step = int(meta.get("step", 0))
            # SourceState: replay the exact rollout stream (env carries,
            # RNG, replay slots). Checkpoints from before the protocol
            # restore learner state only (source starts fresh).
            source_state = ckpt_lib.restore_structured(path, "source")
            if source_state is not None:
                source.load_state_dict(source_state)
            print(f"resumed {path} at step {start_step}"
                  + (" (source state restored)"
                     if source_state is not None else ""))
    runtime = Runtime(source, step_fn, params, opt_state,
                      total_steps=args.steps, start_step=start_step,
                      checkpoint_dir=args.checkpoint_dir,
                      checkpoint_every=args.checkpoint_every,
                      checkpoint_meta=_checkpoint_meta(args), **extras)
    runtime.run()
    return runtime.params


if __name__ == "__main__":
    main()
