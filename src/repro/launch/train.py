"""End-to-end training driver.

Modes:
  rl-agent  — paper-faithful IMPALA: on-device rollouts (catch/gridworld
              envs) + convnet agent + V-trace learner. The MonoBeast/
              PolyBeast host-loop equivalent lives in examples/quickstart.py.
  lm-rl     — IMPALA with an LLM policy on the token-MDP: actors generate
              episodes with the decode path (behavior log-probs recorded),
              learner applies V-trace (DESIGN.md §2).
  lm        — plain next-token pretraining on the synthetic corpus.

Examples:
  PYTHONPATH=src python -m repro.launch.train --mode rl-agent --env catch \
      --steps 500
  PYTHONPATH=src python -m repro.launch.train --mode lm-rl \
      --arch granite-moe-1b-a400m --reduced --steps 50
  PYTHONPATH=src python -m repro.launch.train --mode lm --arch qwen3-4b \
      --reduced --steps 100 --checkpoint-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt_lib
from repro.configs import get_config, get_reduced_config
from repro.configs.atari_impala import small_train
from repro.configs.base import TrainConfig
from repro.core import generate as gen_lib
from repro.core import learner as learner_lib
from repro.core import rollout as rollout_lib
from repro.data import PackedBatchIterator, markov_corpus
from repro.envs import catch, gridworld
from repro.models import model as model_lib
from repro.models.convnet import impala_deep, init_agent, minatar_net
from repro.optim import make_optimizer


def train_rl_agent(args):
    env = {"catch": catch, "gridworld": gridworld}[args.env].make()
    train_cfg = small_train(total_steps=args.steps,
                            learning_rate=args.lr or 2e-3,
                            batch_size=args.batch or 32)
    net = impala_deep if args.agent == "deep" else minatar_net
    init_fn, apply_fn = net(env.obs_shape, env.num_actions)
    params, _ = init_agent(init_fn, jax.random.PRNGKey(train_cfg.seed))
    opt = make_optimizer(train_cfg)
    opt_state = opt.init(params)

    b = train_cfg.batch_size
    key = jax.random.PRNGKey(train_cfg.seed + 1)
    carry = rollout_lib.env_reset_batch(env, key, b)
    unroll = rollout_lib.make_unroll(env, apply_fn, train_cfg.unroll_length)
    train_step = learner_lib.make_train_step(apply_fn, opt, train_cfg)

    @jax.jit
    def combined(params, opt_state, step, carry, key):
        carry, ro = unroll(params, carry, key)
        params, opt_state, metrics = train_step(params, opt_state, step, ro)
        return params, opt_state, carry, metrics

    frames = 0
    t0 = time.time()
    for step in range(args.steps):
        key, k = jax.random.split(key)
        params, opt_state, carry, m = combined(
            params, opt_state, jnp.int32(step), carry, k)
        frames += b * train_cfg.unroll_length
        if step % max(1, args.steps // 20) == 0 or step == args.steps - 1:
            print(f"step {step:5d} frames {frames:9d} "
                  f"reward/step={float(m['reward_per_step']):+.3f} "
                  f"loss={float(m['loss']):+.3f} "
                  f"fps={frames/(time.time()-t0):.0f}")
    _maybe_save(args, {"params": params, "opt_state": opt_state}, args.steps)
    return params


def train_lm_rl(args):
    cfg = (get_reduced_config if args.reduced else get_config)(args.arch)
    train_cfg = TrainConfig(optimizer="adamw", learning_rate=args.lr or 3e-4,
                            grad_clip=1.0, total_steps=args.steps,
                            lr_schedule="constant", entropy_cost=0.003)
    params, _ = model_lib.init(jax.random.PRNGKey(train_cfg.seed), cfg)
    opt = make_optimizer(train_cfg)
    opt_state = opt.init(params)
    train_step = jax.jit(learner_lib.make_lm_train_step(
        cfg, opt, train_cfg, loss_chunk=args.seq))

    b, t = args.batch or 16, args.seq
    a_mod, b_mod = 5, 3
    key = jax.random.PRNGKey(7)
    for step in range(args.steps):
        key, kgen, kprompt = jax.random.split(key, 3)
        prompt = jax.random.randint(kprompt, (b, 1), 0, cfg.vocab_size)
        ep = gen_lib.generate(params, prompt, kgen, cfg=cfg, num_steps=t)
        tokens = ep["tokens"]
        target = (a_mod * tokens[:, :-1] + b_mod) % cfg.vocab_size
        reward = (tokens[:, 1:] == target).astype(jnp.float32)
        done = jnp.zeros((b, t), bool).at[:, -1].set(True)
        batch = {"tokens": tokens, "behavior_logprob": ep["logprob"],
                 "reward": reward, "done": done}
        params, opt_state, m = train_step(params, opt_state,
                                          jnp.int32(step), batch)
        if step % max(1, args.steps // 20) == 0 or step == args.steps - 1:
            print(f"step {step:4d} reward/step="
                  f"{float(m['reward_per_step']):.3f} "
                  f"pg={float(m['pg_loss']):+.2f} "
                  f"H={float(m['entropy_loss']):+.2f}")
    _maybe_save(args, {"params": params, "opt_state": opt_state}, args.steps)
    return params


def train_lm(args):
    cfg = (get_reduced_config if args.reduced else get_config)(args.arch)
    train_cfg = TrainConfig(optimizer="adamw", learning_rate=args.lr or 3e-4,
                            grad_clip=1.0, total_steps=args.steps,
                            lr_schedule="cosine", warmup_steps=10)
    params, _ = model_lib.init(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer(train_cfg)
    opt_state = opt.init(params)
    train_step = jax.jit(learner_lib.make_lm_pretrain_step(
        cfg, opt, loss_chunk=min(512, args.seq)))

    corpus = markov_corpus(cfg.vocab_size, 200_000, seed=1)
    it = PackedBatchIterator(corpus, args.batch or 16, args.seq)
    vision = None
    if cfg.vision_seq:
        vision = jnp.zeros((args.batch or 16, cfg.vision_seq, cfg.d_model),
                           jnp.dtype(cfg.dtype))
    t0 = time.time()
    try:
        for step in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            if vision is not None:
                batch["vision"] = vision
            params, opt_state, m = train_step(params, opt_state,
                                              jnp.int32(step), batch)
            if step % max(1, args.steps // 20) == 0 or step == args.steps - 1:
                toks = (step + 1) * (args.batch or 16) * args.seq
                print(f"step {step:4d} loss={float(m['loss']):.4f} "
                      f"tok/s={toks/(time.time()-t0):.0f}")
    finally:
        it.close()
    _maybe_save(args, {"params": params, "opt_state": opt_state}, args.steps)
    return params


def _maybe_save(args, tree, step):
    if args.checkpoint_dir:
        path = f"{args.checkpoint_dir}/step_{step}.npz"
        ckpt_lib.save(path, tree, {"step": step})
        print("saved", path)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--mode", choices=["rl-agent", "lm-rl", "lm"],
                   default="rl-agent")
    p.add_argument("--env", choices=["catch", "gridworld"], default="catch")
    p.add_argument("--agent", choices=["minatar", "deep"], default="minatar")
    p.add_argument("--arch", default="qwen3-4b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=None)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=None)
    p.add_argument("--checkpoint-dir", default=None)
    args = p.parse_args(argv)
    {"rl-agent": train_rl_agent, "lm-rl": train_lm_rl,
     "lm": train_lm}[args.mode](args)


if __name__ == "__main__":
    main()
