"""Trip-count-corrected roofline accounting.

XLA's HLO cost analysis counts while-loop bodies ONCE (verified in
EXPERIMENTS.md §Dry-run methodology), so a scanned-over-layers model reports
~1/num_groups of its true FLOPs. We correct with a two-program measurement:

  total ≈ cost(full program)            [scan bodies counted once]
        + (G-1) * cost(block program)   [one scan body, lowered standalone]
        + inner-scan corrections        [analytic, for loops *inside* a block
                                         or inside the loss: chunked
                                         attention, loss chunks, mamba/xLSTM
                                         chunk scans]

The block program is the same super-block computation (fwd for serve/prefill,
fwd+bwd-with-remat for train) lowered with the same mesh/rules, so its
collectives and bytes are measured, not modelled. The analytic corrections
use closed-form matmul FLOPs (documented per formula below) and are reported
separately so the measured/modelled split stays visible.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, ModelConfig
from repro.distributed import sharding as shd
from repro.models import blocks as blocks_lib
from repro.models import model as model_lib
from repro.models.common import split_params


def _abstract_block_params(cfg, mesh, rules, pattern=None):
    box = {}

    def f():
        vals, axes = split_params(
            blocks_lib.block_init(jax.random.PRNGKey(0), cfg,
                                  pattern=pattern))
        box["axes"] = axes
        return vals

    shapes = jax.eval_shape(f)
    shardings = shd.param_shardings(box["axes"], mesh, rules, shapes)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)


def _act_spec(cfg, mesh, rules, b, s):
    spec = shd.spec_for(("act_batch", "act_seq", "act_embed"), mesh, rules,
                        (b, s, cfg.d_model))
    return jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.dtype(cfg.dtype),
                                sharding=NamedSharding(mesh, spec))


def build_block_program(cfg: ModelConfig, shape_name: str, mesh, rules):
    """One scan-body program matching model.forward/decode_step's body."""
    ishape = INPUT_SHAPES[shape_name]
    b, s = ishape.global_batch, ishape.seq_len
    kind = ishape.kind

    bp = _abstract_block_params(cfg, mesh, rules)
    shared = (_abstract_block_params(cfg, mesh, rules,
                                     pattern=model_lib.SHARED_PATTERN)
              if cfg.shared_attn_every else None)
    vis = None
    if cfg.vision_seq:
        from repro.launch.specs import _batch_spec, _sds
        vis = _sds((b, cfg.vision_seq, cfg.d_model), jnp.dtype(cfg.dtype),
                   mesh, P(_batch_spec(mesh, b), None, None))

    if kind == "decode":
        from jax.sharding import NamedSharding
        from repro.launch.specs import cache_specs, _sds

        cache = cache_specs(cfg, mesh, b, s)

        def strip_lead(sds):
            # keep the per-leaf sharding, minus the leading groups axis
            parts = list(sds.sharding.spec)
            parts = parts[1:] if parts else []
            return jax.ShapeDtypeStruct(
                sds.shape[1:], sds.dtype,
                sharding=NamedSharding(mesh, P(*parts)))

        cache_slice = jax.tree.map(strip_lead, cache)
        x = _act_spec(cfg, mesh, rules, b, 1)
        pos = jax.ShapeDtypeStruct((), jnp.int32)

        def fn(bp, shared, x, cache_slice, pos):
            with shd.use_rules(mesh, rules):
                x, nc = blocks_lib.block_decode(bp, x, cache_slice["block"],
                                                cfg=cfg, pos=pos)
                if shared is not None:
                    x, _ = blocks_lib.block_decode(
                        shared, x, cache_slice["shared"], cfg=cfg, pos=pos,
                        pattern=model_lib.SHARED_PATTERN)
            return x, nc

        args = (bp, shared, x, cache_slice, pos)
        return fn, args

    x = _act_spec(cfg, mesh, rules, b, s)

    if kind == "prefill":
        def fn(bp, shared, x, vis):
            positions = jnp.arange(s)
            with shd.use_rules(mesh, rules):
                y, aux, cache = blocks_lib.block_apply(
                    bp, x, cfg=cfg, positions=positions, vision=vis,
                    build_cache=True, seq_len=s, dtype=x.dtype)
                if shared is not None:
                    y, _, _ = blocks_lib.block_apply(
                        shared, y, cfg=cfg, positions=positions,
                        pattern=model_lib.SHARED_PATTERN, build_cache=True,
                        seq_len=s, dtype=x.dtype)
            return y, cache

        return fn, (bp, shared, x, vis)

    # train: fwd + remat-backward of one block (the scan body's true cost)
    # Weight grads carry the same ZeRO-2 sharding constraint as the full
    # program (specs.build_train), so the gradient reduction measures as a
    # reduce-scatter, not a full-weight all-reduce.
    def _axes_of(pattern):
        box = {}

        def f():
            vals, axes = split_params(blocks_lib.block_init(
                jax.random.PRNGKey(0), cfg, pattern=pattern))
            box["axes"] = axes
            return vals

        shapes = jax.eval_shape(f)
        return box["axes"], shapes

    bp_axes, bp_shapes = _axes_of(None)
    bp_gshard = shd.zero1_shardings(bp_axes, bp_shapes, mesh, rules)
    sh_gshard = None
    if cfg.shared_attn_every:
        sh_axes, sh_shapes = _axes_of(model_lib.SHARED_PATTERN)
        sh_gshard = shd.zero1_shardings(sh_axes, sh_shapes, mesh, rules)

    def fn(bp, shared, x, vis):
        positions = jnp.arange(s)

        @jax.checkpoint
        def apply(bp, shared, x):
            with shd.use_rules(mesh, rules):
                y, aux, _ = blocks_lib.block_apply(
                    bp, x, cfg=cfg, positions=positions, vision=vis)
                if shared is not None:
                    y, saux, _ = blocks_lib.block_apply(
                        shared, y, cfg=cfg, positions=positions,
                        pattern=model_lib.SHARED_PATTERN)
            return y

        def loss(bp_shared_x):
            bp_, shared_, x_ = bp_shared_x
            y = apply(bp_, shared_, x_)
            return jnp.sum(y.astype(jnp.float32)) * 1e-6

        gbp, gsh, gx = jax.grad(loss)((bp, shared, x))
        gbp = jax.tree.map(jax.lax.with_sharding_constraint, gbp, bp_gshard)
        if gsh is not None:
            gsh = jax.tree.map(jax.lax.with_sharding_constraint, gsh,
                               sh_gshard)
        return gbp, gsh, gx

    return fn, (bp, shared, x, vis)


# ---------------------------------------------------------------------------
# analytic inner-scan corrections (FLOPs; bytes where noted)
# ---------------------------------------------------------------------------

def inner_scan_corrections(cfg: ModelConfig, shape_name: str,
                           chips: int) -> Dict[str, float]:
    """Global FLOPs missing because loops *inside* one block / the loss are
    counted once. Returns extra FLOPs (global, all chips) per source.

    Formulas (per layer, global tokens N_tok = B*S, masked-chunk baseline):
      attn_chunked: kv_step ~ 4*B*H*cq*ckv*hd   -> x (nq*nkv - 1)
      loss_chunks:  chunk  ~ 6*B*c*d*V (fwd+recompute+bwd) -> x (nchunk-1)
      mamba_chunks: chunk  ~ B*L^2*H*(N+P) + 4*B*L*H*P*N   -> x (nc-1)
      mlstm_chunks: chunk  ~ 4*B*L^2*H*dh                  -> x (nc-1)
      slstm_steps:  step   ~ 8*B*H*dh^2                    -> x (S-1)
    """
    ishape = INPUT_SHAPES[shape_name]
    b, s = ishape.global_batch, ishape.seq_len
    kind = ishape.kind
    out = {k: 0.0 for k in ("attn_chunked", "loss_chunks", "mamba_chunks",
                            "mlstm_chunks", "slstm_steps")}
    if kind == "decode":
        return out  # no inner scans in the decode block

    hd = cfg.resolved_head_dim
    h = cfg.num_heads
    d = cfg.d_model

    n_attn = sum(1 for m, _ in cfg.block_pattern
                 if m in ("attn", "local_attn", "swa_attn")) * cfg.num_groups
    n_x = sum(1 for m, _ in cfg.block_pattern
              if m == "xattn") * cfg.num_groups
    if cfg.shared_attn_every:
        n_attn += cfg.num_groups

    if cfg.attn_impl in ("xla_chunked", "xla_chunked_skip", "kernel",
                         "pallas"):
        cq = min(cfg.attn_chunk, s)
        nq = s // cq
        nkv = nq
        per_step = 4.0 * b * h * cq * cq * hd
        out["attn_chunked"] += n_attn * (nq * nkv - 1) * per_step
        if n_x:
            sv = cfg.vision_seq
            ckv = min(cfg.attn_chunk, sv)
            nkv_x = sv // ckv
            out["attn_chunked"] += n_x * (nq * nkv_x - 1) * \
                4.0 * b * h * cq * ckv * hd

    if kind == "train":
        c = min(512, s)
        nchunk = s // c
        out["loss_chunks"] = (nchunk - 1) * 6.0 * b * c * d * cfg.vocab_size

    n_mamba = sum(1 for m, _ in cfg.block_pattern
                  if m == "mamba") * cfg.num_groups
    if n_mamba:
        d_in = cfg.ssm_expand * d
        nh = d_in // cfg.ssm_head_dim
        p_, n_ = cfg.ssm_head_dim, cfg.ssm_state
        L = min(cfg.ssm_chunk, s)
        nc = s // L
        per_chunk = b * L * L * nh * (n_ + p_) + 4.0 * b * L * nh * p_ * n_
        mult = 3.0 if kind == "train" else 1.0  # fwd+recompute+bwd
        out["mamba_chunks"] = n_mamba * (nc - 1) * per_chunk * mult

    n_mlstm = sum(1 for m, _ in cfg.block_pattern
                  if m == "mlstm") * cfg.num_groups
    if n_mlstm:
        dh = d // cfg.num_heads
        L = min(cfg.xlstm_chunk, s)
        nc = s // L
        per_chunk = 4.0 * b * L * L * cfg.num_heads * dh
        mult = 3.0 if kind == "train" else 1.0
        out["mlstm_chunks"] = n_mlstm * (nc - 1) * per_chunk * mult

    n_slstm = sum(1 for m, _ in cfg.block_pattern
                  if m == "slstm") * cfg.num_groups
    if n_slstm:
        dh = d // cfg.num_heads
        per_step = 8.0 * b * cfg.num_heads * dh * dh
        mult = 3.0 if kind == "train" else 1.0
        out["slstm_steps"] = n_slstm * (s - 1) * per_step * mult

    return out


# ---------------------------------------------------------------------------
# per-kernel rooflines (analytic FLOPs/bytes for one kernel invocation)
# ---------------------------------------------------------------------------

def kernel_roofline(kernel: str, *, dtype_bytes: int = 2,
                    **dims) -> Dict[str, float]:
    """Analytic single-chip roofline for ONE invocation of a Pallas kernel.

    FLOPs count the matmul terms (2 per multiply-add; softmax/exp
    elementwise terms are <3% and omitted); bytes are the MINIMAL HBM
    traffic — each operand read once, each output written once — i.e. the
    perfectly-blocked ideal the kernels aim for. ``roofline_s`` is the
    achievable lower bound on one v5e chip (mesh.PEAK_FLOPS_BF16 /
    mesh.HBM_BW); benchmarks/run.py --suite kernels reports
    measured_s / roofline_s as the achieved-vs-roofline ratio.

    Dims per kernel:
      flash_attention   b, h, kh, s, hd [, window, causal=True]
      decode_attention  b, h, kh, s, hd
      ssd_chunk         bh, l, n, p
      vtrace            t, b
    """
    from repro.launch import mesh as mesh_lib
    if kernel == "flash_attention":
        b, h, kh = dims["b"], dims["h"], dims["kh"]
        s, hd = dims["s"], dims["hd"]
        window = dims.get("window", 0)
        # visited (q, kv) pairs: causal halves the square; a sliding
        # window caps each query's kv span
        s_eff = min(window, s) if window else (s + 1) / 2.0
        if not dims.get("causal", True):
            s_eff = s
        flops = 4.0 * b * h * s * s_eff * hd           # qk^T + pv
        bytes_ = dtype_bytes * (2 * b * h * s * hd      # q + o
                                + 2 * b * kh * s * hd)  # k + v (unexpanded)
    elif kernel == "decode_attention":
        b, h, kh = dims["b"], dims["h"], dims["kh"]
        s, hd = dims["s"], dims["hd"]
        flops = 4.0 * b * h * s * hd
        bytes_ = dtype_bytes * (2 * b * kh * s * hd     # streamed k + v
                                + 2 * b * h * hd)       # q + o
    elif kernel == "ssd_chunk":
        bh, L, n, p = dims["bh"], dims["l"], dims["n"], dims["p"]
        # G = C B^T (2L^2n); y_diag = (G.decay) X (2L^2p);
        # state update + y_off (2Lnp each)
        flops = bh * (2.0 * L * L * (n + p) + 4.0 * L * n * p)
        bytes_ = dtype_bytes * bh * (2 * L * n + 2 * L * p + 2 * p * n + L)
    elif kernel == "vtrace":
        t, b = dims["t"], dims["b"]
        flops = 3.0 * t * b                             # one fma + mul per cell
        bytes_ = 4 * 3 * t * b                          # deltas, dcs, out fp32
    else:
        raise ValueError(f"unknown kernel {kernel}")
    compute_s = flops / mesh_lib.PEAK_FLOPS_BF16
    memory_s = bytes_ / mesh_lib.HBM_BW
    return {
        "flops": flops,
        "bytes": bytes_,
        "intensity": flops / bytes_ if bytes_ else 0.0,
        "roofline_s": max(compute_s, memory_s),
        "bound": "compute" if compute_s >= memory_s else "memory",
    }


def kernel_rooflines(cfg: ModelConfig, shape_name: str) -> Dict[str, Dict]:
    """Per-arch kernel roofline table: for every Pallas kernel with a hot
    path in this (cfg, input-shape), the analytic single-invocation
    roofline plus how many invocations one step performs
    (``calls_per_step`` = layers x inner chunks). Archs without the mixer
    simply omit the kernel."""
    ishape = INPUT_SHAPES[shape_name]
    b, s = ishape.global_batch, ishape.seq_len
    kind = ishape.kind
    dtype_bytes = jnp.dtype(cfg.dtype).itemsize
    hd = cfg.resolved_head_dim

    n_attn = sum(1 for m, _ in cfg.block_pattern
                 if m in ("attn", "local_attn", "swa_attn")) * cfg.num_groups
    if cfg.shared_attn_every:
        n_attn += cfg.num_groups
    n_mamba = sum(1 for m, _ in cfg.block_pattern
                  if m == "mamba") * cfg.num_groups

    out: Dict[str, Dict] = {}
    if n_attn:
        if kind == "decode":
            rl = kernel_roofline("decode_attention", dtype_bytes=dtype_bytes,
                                 b=b, h=cfg.num_heads, kh=cfg.num_kv_heads,
                                 s=s, hd=hd)
            rl["calls_per_step"] = n_attn
            out["decode_attention"] = rl
        else:
            rl = kernel_roofline("flash_attention", dtype_bytes=dtype_bytes,
                                 b=b, h=cfg.num_heads, kh=cfg.num_kv_heads,
                                 s=s, hd=hd,
                                 window=(cfg.sliding_window if all(
                                     m in ("swa_attn", "local_attn")
                                     for m, _ in cfg.block_pattern
                                     if m.endswith("attn")) else 0))
            rl["calls_per_step"] = n_attn * (3 if kind == "train" else 1)
            out["flash_attention"] = rl
    if n_mamba and kind != "decode":
        d_in = cfg.ssm_expand * cfg.d_model
        nh = d_in // cfg.ssm_head_dim
        L = min(cfg.ssm_chunk, s)
        rl = kernel_roofline("ssd_chunk", dtype_bytes=4,  # fp32 state math
                             bh=b * nh, l=L, n=cfg.ssm_state,
                             p=cfg.ssm_head_dim)
        rl["calls_per_step"] = n_mamba * (s // L) * (3 if kind == "train"
                                                     else 1)
        out["ssd_chunk"] = rl
    if kind == "train":
        rl = kernel_roofline("vtrace", t=s, b=b)
        rl["calls_per_step"] = 1
        out["vtrace"] = rl
    return out
