"""Multi-host / multi-pod process bootstrap for real TPU deployments.

On a v5e pod slice every host runs the same binary; `jax.distributed`
wires them into one global device mesh. This module is the thin entry
point the scheduler invokes on each host:

  # per-host, via your scheduler (GKE/xmanager/gcloud):
  python -m repro.launch.multihost --coordinator $COORD:1234 \
      --num-processes $NPROC --process-id $ID \
      --mode train --arch qwen3-32b --shape train_4k [--multi-pod]

On CPU CI this degrades to a single-process run (no --coordinator), which
is how it is smoke-tested. The actual step execution reuses
launch/specs.py program builders — the same programs the dry-run proves.
"""

from __future__ import annotations

import argparse
import time


def bootstrap(coordinator=None, num_processes=1, process_id=0):
    """Wire this process into the global device mesh (no-op single-host
    when ``coordinator`` is None). Shared by this entry point and
    ``launch/train.py --coordinator``; must run before any jax device
    query so ``jax.devices()`` returns the GLOBAL device set."""
    import jax
    if coordinator:
        # On the CPU backend multi-process SPMD (device_put onto
        # non-addressable shardings, jitted collectives, checkpoint
        # reassembly via make_array_from_single_device_arrays) only works
        # with the gloo cross-host collectives implementation; the default
        # raises "Multiprocess computations aren't implemented on the CPU
        # backend". Must be set BEFORE jax.distributed.initialize. No-op
        # for TPU/GPU backends, which ignore the cpu_collectives knob.
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
        jax.distributed.initialize(
            coordinator_address=coordinator,
            num_processes=num_processes,
            process_id=process_id)
    print(f"[host {process_id}] devices: local={jax.local_device_count()}"
          f" global={jax.device_count()}")
    return jax


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--coordinator", default=None,
                   help="host:port of process 0 (omit for single-host)")
    p.add_argument("--num-processes", type=int, default=1)
    p.add_argument("--process-id", type=int, default=0)
    p.add_argument("--mode", choices=["train", "serve", "dryrun"],
                   default="dryrun")
    p.add_argument("--arch", default="qwen3-4b")
    p.add_argument("--shape", default="train_4k")
    p.add_argument("--rules", default="auto")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--steps", type=int, default=10)
    args = p.parse_args(argv)

    jax = bootstrap(args.coordinator, args.num_processes, args.process_id)

    from repro.distributed.sharding import RULE_SETS
    from repro.launch import mesh as mesh_lib
    from repro.launch.dryrun import resolve_rules
    from repro.launch.specs import build_program

    if jax.device_count() >= 512:
        mesh = mesh_lib.make_production_mesh(multi_pod=args.multi_pod)
    else:
        # whatever this deployment actually has: factor into (data, model)
        n = jax.device_count()
        model = 1
        for m in (16, 8, 4, 2, 1):
            if n % m == 0:
                model = m
                break
        from repro.launch.mesh import make_mesh2d
        mesh = make_mesh2d(n // model, model)
    print(f"[host {args.process_id}] mesh {dict(mesh.shape)}")

    rules_name = resolve_rules(args.rules, args.shape, args.arch)
    step_fn, specs, cfg, jit_kwargs = build_program(
        args.arch, args.shape, mesh, RULE_SETS[rules_name])

    t0 = time.time()
    with mesh:
        compiled = jax.jit(step_fn, **jit_kwargs).lower(*specs).compile()
    print(f"[host {args.process_id}] compiled {args.arch}/{args.shape} "
          f"({rules_name}) in {time.time()-t0:.0f}s")

    if args.mode == "dryrun":
        print(compiled.memory_analysis())
        return

    # train/serve: materialise the inputs on-mesh and run real steps.
    # (On a multi-host TPU each process contributes its local shard; the
    # jitted callable handles the donated params/opt-state rebinding.)
    import jax.numpy as jnp

    def materialise(sds):
        if jnp.issubdtype(sds.dtype, jnp.floating):
            return jax.jit(
                lambda: 0.01 * jax.random.normal(
                    jax.random.PRNGKey(0), sds.shape, sds.dtype),
                out_shardings=sds.sharding)()
        return jax.jit(lambda: jnp.zeros(sds.shape, sds.dtype),
                       out_shardings=getattr(sds, "sharding", None))()

    conc = jax.tree.map(materialise, specs)
    fn = jax.jit(step_fn, **jit_kwargs)
    with mesh:
        if args.mode == "train":
            params, opt_state, step_c, batch = conc
            for step in range(args.steps):
                params, opt_state, metrics = fn(params, opt_state,
                                                jnp.int32(step), batch)
            jax.block_until_ready(metrics["loss"])
            print(f"[host {args.process_id}] {args.steps} train steps OK "
                  f"loss={float(metrics['loss']):.4f}")
        else:  # serve
            if len(conc) == 4:      # decode: (params, tokens, cache, pos)
                params, tokens, cache, pos = conc
                for step in range(args.steps):
                    logits, baseline, cache = fn(params, tokens, cache,
                                                 jnp.int32(step + 1))
                jax.block_until_ready(logits)
            else:                    # prefill
                out = fn(*conc)
                jax.block_until_ready(jax.tree.leaves(out)[0])
            print(f"[host {args.process_id}] serve steps OK")


if __name__ == "__main__":
    main()
