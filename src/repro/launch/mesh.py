"""Production mesh construction (TPU v5e target).

Single pod: 256 chips as (16, 16) ("data", "model").
Multi-pod:  2 pods x 256 chips as (2, 16, 16) ("pod", "data", "model") —
the "pod" axis is an additional data axis; gradient all-reduce crosses the
inter-pod links once per step (DESIGN.md §7).

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before any device query).
"""

from __future__ import annotations

import jax


def make_mesh(shape, axes, devices=None):
    """``jax.make_mesh`` with Auto axis types where this jax supports them
    (``jax.sharding.AxisType`` does not exist on older 0.4.x releases).
    ``devices``: optional explicit device array (defaults to all local
    devices, as ``jax.make_mesh`` does)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if devices is not None:
        import numpy as np
        devices = np.asarray(devices).reshape(shape)
        if axis_type is None:
            return jax.sharding.Mesh(devices, axes)
        return jax.sharding.Mesh(devices, axes,
                                 axis_types=(axis_type.Auto,) * len(axes))
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally, as a 1-D 'data' mesh (CPU tests)."""
    n = len(jax.devices())
    return make_mesh((n,), ("data",))


def make_mesh2d(data=1, model=1, devices=None):
    """The first ``data * model`` devices as a 2-D ("data", "model") mesh —
    the LM-path learner mesh (``--mesh-data N --mesh-model M``).

    ``devices`` defaults to the GLOBAL device set (``jax.devices()``), so
    under a ``jax.distributed`` bootstrap (launch/multihost.py, or
    ``train.py --coordinator``) the same call builds the whole-pod mesh;
    on CPU force host devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``. At
    ``(data=1, model=1)`` the learner programs built on this mesh are
    bit-identical to the unmeshed ones (tests/test_mesh2d.py).
    """
    devices = jax.devices() if devices is None else list(devices)
    n = data * model
    if n > len(devices):
        raise ValueError(
            f"mesh ({data}, {model}) needs {n} devices but only "
            f"{len(devices)} visible (on CPU set "
            "XLA_FLAGS=--xla_force_host_platform_device_count)")
    return make_mesh((data, model), ("data", "model"), devices=devices[:n])


def make_data_mesh(n=None):
    """The first ``n`` local devices as a 1-D ("data",) mesh — the
    data-parallel RL learner mesh (``--mesh-data N``). On CPU, run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to get N>1."""
    devices = jax.devices()
    n = len(devices) if n is None else n
    if n > len(devices):
        raise ValueError(
            f"--mesh-data {n} but only {len(devices)} devices visible "
            "(on CPU set XLA_FLAGS=--xla_force_host_platform_device_count)")
    return make_mesh((n,), ("data",), devices=devices[:n])


# Hardware constants for the roofline model (TPU v5e)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (~per chip per direction)
HBM_BYTES = 16 * 1024**3      # 16 GiB per chip
VMEM_BYTES = 128 * 1024**2    # ~128 MiB vector memory (v5e)
