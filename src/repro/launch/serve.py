"""Continuous-batching inference server on the DecodeSession API.

The PolyBeast inference-queue idea (keep accelerator evaluations batched)
taken to its serving conclusion: instead of draining fixed batches and
running each to completion (head-of-line blocking on the longest
generation), the server owns one ``core.generate.DecodeSession`` and
re-decides the batch EVERY step — finished requests are evicted and
queued requests admitted into the freed slots while the survivors keep
decoding. ``--policy static`` keeps the old drain-and-run behaviour as a
baseline; ``benchmarks/run.py --suite serving`` measures both.

Client API (request handles, not blocking arrays):

    h = server.submit(prompt, max_tokens=64, temperature=0.8,
                      stop_token=eos)
    tokens = h.result(timeout=30)     # (P + generated,) int32

A single-request server is bitwise-identical to ``core.generate.generate``
with the same seed (see tests/test_decode_session.py).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
      --requests 24 --gen-tokens 16
"""

from __future__ import annotations

import argparse
import collections
import sys
import threading
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced_config
from repro.configs.base import ImplContext
from repro.core.generate import DecodeSession
from repro.models import model as model_lib


class RequestHandle:
    """Future-style handle for one submitted request."""

    def __init__(self, prompt: np.ndarray):
        self.prompt = prompt
        self._event = threading.Event()
        self._tokens = None
        self._error = None
        self.t_submit = time.monotonic()
        self.t_first = None           # first generated token (prefill done)
        self.t_done = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> np.ndarray:
        """Block until complete; returns (P + generated,) int32 tokens
        (prompt echoed, stop token included when hit)."""
        if not self._event.wait(timeout):
            raise TimeoutError("request not complete")
        if self._error is not None:
            raise self._error
        return self._tokens

    # -- server side --------------------------------------------------------

    def _complete(self, tokens: np.ndarray) -> None:
        self._tokens = tokens
        self.t_done = time.monotonic()
        self._event.set()

    def _fail(self, err: Exception) -> None:
        self._error = err
        self.t_done = time.monotonic()
        self._event.set()


class _Request:
    __slots__ = ("handle", "prompt", "max_tokens", "temperature",
                 "stop_token", "key", "tokens", "slot")

    def __init__(self, handle, prompt, max_tokens, temperature, stop_token,
                 key):
        self.handle = handle
        self.prompt = prompt
        self.max_tokens = max_tokens
        self.temperature = temperature
        self.stop_token = stop_token
        self.key = key
        self.tokens: list = []


class Server:
    """Continuous-batching server over one DecodeSession.

    policy='continuous': admission/eviction every step (default).
    policy='static':     admit only into an EMPTY batch and run it until
                         every member finishes — the fixed-batch baseline.
    """

    def __init__(self, cfg, params, *, max_batch: int = 8,
                 max_len: int = 256, policy: str = "continuous",
                 default_max_tokens: int = 16, mesh=None, rules=None,
                 seed: int = 0):
        if policy not in ("continuous", "static"):
            raise ValueError(f"unknown policy {policy!r}")
        self.cfg = cfg
        self.policy = policy
        self.default_max_tokens = default_max_tokens
        self.session = DecodeSession(params, cfg, max_batch=max_batch,
                                     max_len=max_len, mesh=mesh, rules=rules)
        self._key = jax.random.PRNGKey(seed)
        self._cv = threading.Condition()
        self._queue: collections.deque = collections.deque()
        self._inflight: dict = {}     # slot -> _Request
        self._closed = False
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self.served = 0
        self.steps = 0                # decode steps executed
        self.tokens_out = 0           # generated tokens (incl. prefill's)

    def start(self) -> "Server":
        self._thread.start()
        return self

    def stop(self) -> None:
        """Close the queue; in-flight and queued requests still complete."""
        with self._cv:
            self._closed = True
            self._cv.notify()
        self._thread.join(timeout=60)

    def submit(self, prompt, *, max_tokens: int | None = None,
               temperature: float = 1.0, stop_token: int | None = None,
               key=None) -> RequestHandle:
        """Enqueue a request (any thread). ``key`` pins the sampling PRNG
        key (parity tests); None draws from the server's stream."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if not 0 < prompt.shape[0] < self.session.max_len:
            raise ValueError(
                f"prompt length {prompt.shape[0]} not in "
                f"[1, {self.session.max_len})")
        handle = RequestHandle(prompt)
        n = max_tokens if max_tokens is not None else self.default_max_tokens
        n = min(n, self.session.max_len - prompt.shape[0])
        with self._cv:
            if self._closed:
                raise RuntimeError("server is stopped")
            if key is None:
                self._key, key = jax.random.split(self._key)
            self._queue.append(_Request(handle, prompt, n, temperature,
                                        stop_token, np.asarray(key)))
            self._cv.notify()
        return handle

    # -- server thread ------------------------------------------------------

    def _free_slot(self):
        """First slot neither active nor reserved by a pending admission."""
        active = self.session.active
        for s in range(self.session.max_batch):
            if not active[s] and s not in self._inflight:
                return s
        return None

    def _admissible(self) -> bool:
        if not self._queue or self._free_slot() is None:
            return False
        return self.policy == "continuous" or not self._inflight

    def _finish(self, slot: int) -> None:
        req = self._inflight.pop(slot)
        self.session.evict(slot)
        req.handle._complete(np.concatenate(
            [req.prompt, np.asarray(req.tokens, np.int32)]))
        self.served += 1

    def _took(self, slot: int, token: int) -> None:
        """Record one generated token; finish the request on stop/budget."""
        req = self._inflight[slot]
        req.tokens.append(token)
        self.tokens_out += 1
        if req.handle.t_first is None:
            req.handle.t_first = time.monotonic()
        if token == req.stop_token or len(req.tokens) >= req.max_tokens:
            self._finish(slot)

    def _loop(self) -> None:
        while True:
            reqs = []
            with self._cv:
                while (not self._closed and not self._queue
                       and not self._inflight):
                    self._cv.wait(timeout=0.5)
                if (self._closed and not self._queue
                        and not self._inflight):
                    return
                while self._admissible():
                    # reserve the slot now so _admissible stays accurate
                    slot = self._free_slot()
                    req = self._queue.popleft()
                    req.slot = slot
                    self._inflight[slot] = req
                    reqs.append(req)
            for req in reqs:   # prefill outside the lock (slow)
                slot = req.slot
                try:
                    out = self.session.prefill_into(
                        slot, req.prompt, key=req.key,
                        temperature=req.temperature)
                except Exception as e:  # noqa: BLE001
                    self._inflight.pop(slot)
                    req.handle._fail(e)
                    continue
                self._took(slot, int(out["token"]))
            if self._inflight:
                out = self.session.step()
                self.steps += 1
                for slot in list(self._inflight):
                    self._took(slot, int(out["token"][slot]))


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-4b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--requests", type=int, default=24)
    p.add_argument("--prompt-len", type=int, default=15,
                   help="max prompt length (lengths drawn in [1, this])")
    p.add_argument("--gen-tokens", type=int, default=16,
                   help="max generation budget (per-request budgets drawn "
                        "in [1, this])")
    p.add_argument("--max-batch", type=int, default=8)
    p.add_argument("--max-len", type=int, default=0,
                   help="slot capacity (0: prompt-len + gen-tokens)")
    p.add_argument("--policy", default="continuous",
                   choices=["continuous", "static"])
    p.add_argument("--attn-impl", default=None,
                   choices=["xla", "xla_chunked", "xla_chunked_skip",
                            "kernel"],
                   help="'kernel': Pallas flash kernel for prefill + "
                        "decode-attention kernel per generated token "
                        "(interpret-mode on CPU)")
    p.add_argument("--ssd-impl", default=None, choices=["xla", "kernel"],
                   help="Mamba2 chunk-scan impl for prefill")
    args = p.parse_args(argv)

    cfg = (get_reduced_config if args.reduced else get_config)(args.arch)
    cfg = ImplContext.from_args(args).apply(cfg)
    params, _ = model_lib.init(jax.random.PRNGKey(0), cfg)
    max_len = args.max_len or args.prompt_len + args.gen_tokens
    server = Server(cfg, params, max_batch=args.max_batch, max_len=max_len,
                    policy=args.policy,
                    default_max_tokens=args.gen_tokens).start()

    rng = np.random.default_rng(0)
    t0 = time.time()
    handles = []
    for _ in range(args.requests):
        plen = int(rng.integers(1, args.prompt_len + 1))
        prompt = rng.integers(0, cfg.vocab_size, size=plen)
        handles.append(server.submit(
            prompt, max_tokens=int(rng.integers(1, args.gen_tokens + 1))))
    results = [h.result(timeout=600) for h in handles]
    dt = time.time() - t0
    server.stop()

    ok = all(np.array_equal(r[:h.prompt.shape[0]], h.prompt)
             for r, h in zip(results, handles))
    print(f"served {server.served} requests / {server.tokens_out} tokens "
          f"in {server.steps} decode steps ({dt:.2f}s, "
          f"{server.tokens_out/dt:.0f} tok/s, policy={args.policy}); "
          f"prompt-echo check: {'OK' if ok else 'FAIL'}")
    if not ok or server.served != args.requests:
        sys.exit(1)


if __name__ == "__main__":
    main()
