"""Batched serving driver: the PolyBeast inference-queue architecture
applied to LLM serving.

Request threads submit prompts to a DynamicBatcher; the server thread
drains batches, pads them to the bucket ladder, runs prefill + N decode
steps with the compiled generate() path, and scatters responses back.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
      --requests 24 --gen-tokens 16
"""

from __future__ import annotations

import argparse
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced_config
from repro.core import generate as gen_lib
from repro.core.batcher import Closed, DynamicBatcher
from repro.models import model as model_lib


class Server:
    def __init__(self, cfg, params, *, gen_tokens: int, max_batch: int = 8,
                 timeout_ms: float = 5.0, attn_impl=None):
        self.cfg = cfg
        self.params = params
        self.gen_tokens = gen_tokens
        self.attn_impl = attn_impl
        self.batcher = DynamicBatcher(max_batch_size=max_batch,
                                      timeout_ms=timeout_ms)
        self._key = jax.random.PRNGKey(0)
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self.served = 0
        self.batches = 0

    def start(self):
        self._thread.start()

    def stop(self):
        self.batcher.close()
        self._thread.join(timeout=10)

    def submit(self, prompt: np.ndarray) -> np.ndarray:
        """Blocking request API (called from client threads)."""
        return self.batcher.compute(prompt.astype(np.int32))

    def _loop(self):
        while True:
            try:
                got = self.batcher.get_batch(timeout=0.5)
            except Closed:
                return
            if got is None:
                continue
            prompts, respond, n = got
            self._key, k = jax.random.split(self._key)
            out = gen_lib.generate(self.params, jnp.asarray(prompts), k,
                                   cfg=self.cfg, num_steps=self.gen_tokens,
                                   attn_impl=self.attn_impl)
            respond(np.asarray(out["tokens"]))
            self.served += n
            self.batches += 1


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-4b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--requests", type=int, default=24)
    p.add_argument("--prompt-len", type=int, default=15)
    p.add_argument("--gen-tokens", type=int, default=16)
    p.add_argument("--max-batch", type=int, default=8)
    p.add_argument("--attn-impl", default=None,
                   choices=["xla", "xla_chunked", "xla_chunked_skip",
                            "kernel"],
                   help="'kernel': Pallas flash kernel for prefill + "
                        "decode-attention kernel per generated token "
                        "(interpret-mode on CPU)")
    args = p.parse_args(argv)

    cfg = (get_reduced_config if args.reduced else get_config)(args.arch)
    params, _ = model_lib.init(jax.random.PRNGKey(0), cfg)
    server = Server(cfg, params, gen_tokens=args.gen_tokens,
                    max_batch=args.max_batch, attn_impl=args.attn_impl)
    server.start()

    results = {}
    lock = threading.Lock()
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(args.requests, args.prompt_len))

    def client(i):
        out = server.submit(prompts[i])
        with lock:
            results[i] = out

    t0 = time.time()
    threads = [threading.Thread(target=client, args=(i,))
               for i in range(args.requests)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.time() - t0

    ok = all(np.array_equal(results[i][:args.prompt_len], prompts[i])
             for i in range(args.requests))
    print(f"served {server.served} requests in {server.batches} batches "
          f"({dt:.2f}s, {server.served*args.gen_tokens/dt:.0f} tok/s); "
          f"prompt-echo check: {'OK' if ok else 'FAIL'}")
    server.stop()


if __name__ == "__main__":
    main()
