import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) program on
the production meshes and extract memory / cost / collective statistics.

The two lines above MUST stay the first statements in this module: jax locks
the device count on first backend initialisation, and the dry-run needs 512
host placeholder devices. (Only the dry-run — tests and benchmarks see the
real single CPU device.)

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  python -m repro.launch.dryrun --all                 # 40 pairs, single-pod
  python -m repro.launch.dryrun --all --multi-pod     # 40 pairs, 512 chips
  python -m repro.launch.dryrun --all --rules fsdp    # alternative sharding

Default --rules auto picks per (arch, shape): expert(_seqpar) when the
expert count divides the model axis, fsdp(_seqpar) for >=8B train /
>=60B serve, seqpar for other train shapes, megatron otherwise —
the measured rationale is EXPERIMENTS.md §Perf.

Each run writes <out>/<arch>__<shape>__<mesh>__<rules>.json with
bytes-per-device, trip-count-corrected per-device FLOPs/bytes,
per-collective byte counts, and the roofline terms (EXPERIMENTS.md
§Dry-run / §Roofline). Recorded sweeps live in
experiments/dryrun_baseline/ and experiments/dryrun_optimized/.
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.configs import ARCHS
from repro.configs.base import INPUT_SHAPES, ImplContext
from repro.distributed.sharding import RULE_SETS
from repro.launch import mesh as mesh_lib
from repro.launch.specs import build_program

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|"
                       r"u64|u32|u16|u8|pred|c64|c128)\[([\d,]*)\]")

# bytes-on-the-wire multiplier per output byte (ring algorithms)
_COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def collective_bytes(hlo_text: str):
    """Sum per-device collective bytes from optimized (SPMD) HLO text."""
    per_op = {}
    for m in _COLL_RE.finditer(hlo_text):
        out_sig, op = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(out_sig):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        per_op[op] = per_op.get(op, 0) + nbytes * _COLL_FACTOR[op]
    return per_op


def model_flops(cfg, shape, chips: int) -> float:
    """Useful-compute estimate (global): 6·N·D train, 2·N·D inference.
    MoE uses active params (top-k experts)."""
    n = cfg.param_count()
    if cfg.num_experts:
        inactive = cfg.num_groups * len(cfg.block_pattern) * \
            (cfg.num_experts - cfg.num_experts_per_tok) * \
            3 * cfg.d_model * cfg.moe_d_ff
        n -= inactive
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def resolve_rules(rules_name: str, shape_name: str, arch: str) -> str:
    """'auto' baseline rules (EXPERIMENTS.md §Perf entry 0):
      train: seq-parallel residuals (required to fit saved scan carries);
             + FSDP weights for >=8B models (weights/grads/opt do not fit
             a 16-way model axis alone).
      serve: megatron-2D; FSDP for >=60B (llama-90b weights alone exceed
             HBM on the model axis)."""
    if rules_name != "auto":
        return rules_name
    from repro.configs import get_config
    cfg = get_config(arch)
    n = cfg.param_count()
    # expert parallelism when the expert count divides the model axis
    # (granite: 32 experts / 16; mixtral's 8 does not divide — megatron):
    # §Perf H2 — 6x collective reduction, removes replicated expert compute.
    ep = cfg.num_experts and cfg.num_experts % 16 == 0
    if INPUT_SHAPES[shape_name].kind == "train":
        if ep:
            return "expert_seqpar"
        return "fsdp_seqpar" if n >= 8e9 else "seqpar"
    if ep:
        return "expert"
    return "fsdp" if n >= 60e9 else "megatron"


def _analyze(compiled):
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    coll = collective_bytes(compiled.as_text())
    return mem, float(ca.get("flops", 0.0)), \
        float(ca.get("bytes accessed", 0.0)), coll


def run_one(arch: str, shape_name: str, *, multi_pod: bool, rules_name: str,
            out_dir: str, verbose: bool = True, with_block: bool = True,
            impls=None):
    from repro.launch.roofline import (build_block_program,
                                       inner_scan_corrections,
                                       kernel_rooflines)
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    rules_name = resolve_rules(rules_name, shape_name, arch)
    rules = RULE_SETS[rules_name]
    shape = INPUT_SHAPES[shape_name]
    chips = mesh.size
    mesh_name = "2x16x16" if multi_pod else "16x16"

    t0 = time.time()
    step_fn, args, cfg, jit_kwargs = build_program(arch, shape_name, mesh,
                                                   rules, impls=impls)
    with mesh:
        lowered = jax.jit(step_fn, **jit_kwargs).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem, flops_dev, bytes_dev, coll = _analyze(compiled)
    coll_total = sum(coll.values())

    # ---- trip-count correction: + (G-1) x one-scan-body program ----
    block = {"flops_per_device": 0.0, "bytes_per_device": 0.0,
             "collective_bytes": 0.0}
    if with_block:
        bfn, bargs = build_block_program(cfg, shape_name, mesh, rules)
        with mesh:
            bcompiled = jax.jit(bfn).lower(*bargs).compile()
        _, bflops, bbytes, bcoll = _analyze(bcompiled)
        block = {"flops_per_device": bflops, "bytes_per_device": bbytes,
                 "collective_bytes": sum(bcoll.values())}
    g1 = cfg.num_groups - 1
    corr = inner_scan_corrections(cfg, shape_name, chips)
    corr_flops_dev = sum(corr.values()) / chips

    flops_dev_c = flops_dev + g1 * block["flops_per_device"] + corr_flops_dev
    bytes_dev_c = bytes_dev + g1 * block["bytes_per_device"]
    coll_total_c = coll_total + g1 * block["collective_bytes"]

    mflops = model_flops(cfg, shape, chips)
    compute_t = flops_dev_c / mesh_lib.PEAK_FLOPS_BF16
    memory_t = bytes_dev_c / mesh_lib.HBM_BW
    coll_t = coll_total_c / mesh_lib.ICI_BW
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": coll_t}
    bottleneck = max(terms, key=terms.get)

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "rules": rules_name, "chips": chips,
        "kind": shape.kind,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_device_total": (mem.argument_size_in_bytes
                                 + mem.output_size_in_bytes
                                 + mem.temp_size_in_bytes
                                 - mem.alias_size_in_bytes),
        },
        "cost_raw": {"flops_per_device": flops_dev,
                     "bytes_per_device": bytes_dev,
                     "collective_bytes_per_device": coll_total},
        "cost_block": block,
        "inner_scan_corrections_global_flops": corr,
        "cost_corrected": {"flops_per_device": flops_dev_c,
                           "bytes_per_device": bytes_dev_c,
                           "collective_bytes_per_device": coll_total_c},
        "collectives": coll,
        "roofline": {
            **{k: round(v, 6) for k, v in terms.items()},
            "bottleneck": bottleneck,
            "model_flops_global": mflops,
            "hlo_flops_global": flops_dev_c * chips,
            "useful_ratio": (mflops / (flops_dev_c * chips)
                             if flops_dev_c else 0.0),
        },
        "params": cfg.param_count(),
        # analytic per-kernel rooflines for this (arch, shape): what each
        # Pallas kernel SHOULD cost on one chip — the achieved-vs-roofline
        # denominator benchmarks/run.py --suite kernels measures against.
        "kernel_rooflines": kernel_rooflines(cfg, shape_name),
    }

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}__{shape_name}__{mesh_name}__{rules_name}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(result, f, indent=1)

    if verbose:
        hbm_frac = result["memory"]["per_device_total"] / mesh_lib.HBM_BYTES
        print(f"[{arch} | {shape_name} | {mesh_name} | {rules_name}] "
              f"compile={t_compile:.0f}s "
              f"mem/dev={result['memory']['per_device_total']/2**30:.2f}GiB "
              f"({hbm_frac*100:.0f}% HBM) "
              f"flops/dev={flops_dev_c:.3g} coll/dev={coll_total_c:.3g}B "
              f"bottleneck={bottleneck} "
              f"useful={result['roofline']['useful_ratio']:.2f}",
              flush=True)
        print("  memory_analysis:", mem, flush=True)
        print("  cost_analysis (corrected): flops=%.4g bytes=%.4g" %
              (flops_dev_c, bytes_dev_c), flush=True)
    return result


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=list(ARCHS), default=None)
    p.add_argument("--shape", choices=list(INPUT_SHAPES), default=None)
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--rules", choices=["auto"] + list(RULE_SETS),
                   default="auto")
    p.add_argument("--attn-impl", default=None,
                   choices=["xla", "xla_chunked", "xla_chunked_skip",
                            "kernel"],
                   help="attention impl for the lowered programs "
                        "(default: the memory-bounded xla_chunked)")
    p.add_argument("--ssd-impl", default=None, choices=["xla", "kernel"],
                   help="Mamba2 chunk-scan impl for the lowered programs")
    p.add_argument("--out", default="experiments/dryrun")
    args = p.parse_args(argv)

    pairs = []
    if args.all:
        for arch in ARCHS:
            for shape in INPUT_SHAPES:
                pairs.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs.append((args.arch, args.shape))

    failures = []
    for arch, shape in pairs:
        try:
            run_one(arch, shape, multi_pod=args.multi_pod,
                    rules_name=args.rules, out_dir=args.out,
                    impls=ImplContext.from_args(args))
        except Exception as e:  # noqa: BLE001
            failures.append((arch, shape, repr(e)))
            print(f"[{arch} | {shape}] FAILED: {e}", flush=True)
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures:")
        for f in failures:
            print(" ", f)
        sys.exit(1)
    print(f"\nall {len(pairs)} dry-runs compiled OK")


if __name__ == "__main__":
    main()
