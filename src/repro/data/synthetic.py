"""Synthetic token data pipeline (no external datasets in the container).

Provides deterministic, seedable streams for the LM/RL drivers:

* ``markov_corpus`` — tokens from a random sparse Markov chain (low-entropy,
  so LM training loss visibly decreases; used by examples and tests).
* ``PackedBatchIterator`` — documents packed into fixed (B, S+1) batches
  with host-side prefetch, the shape consumed by the learner steps.
* ``rl_episode_batch`` — token-MDP episode batches with behavior log-probs,
  rewards and dones (the LLM-IMPALA learner-queue format).
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np


def markov_corpus(vocab_size: int, length: int, seed: int = 0,
                  branching: int = 4) -> np.ndarray:
    """Random sparse Markov chain: each token has ``branching`` successors."""
    rng = np.random.default_rng(seed)
    succ = rng.integers(0, vocab_size, size=(vocab_size, branching))
    probs = rng.dirichlet(np.ones(branching), size=vocab_size)
    out = np.empty(length, np.int32)
    tok = int(rng.integers(vocab_size))
    for i in range(length):
        out[i] = tok
        tok = int(succ[tok, rng.choice(branching, p=probs[tok])])
    return out


class PackedBatchIterator:
    """Yields {"tokens": (B, S+1) int32} batches from a corpus, with a
    background prefetch thread (the host data-pipeline substrate).

    Checkpointable: batch ``i`` is derived from ``(seed, i)`` alone (an
    independent per-batch Generator), so the stream position is just a
    (seed, offset) pair — ``state_dict``/``load_state_dict`` let a resumed
    ``--mode lm`` run replay the EXACT batch sequence of an uninterrupted
    one (prefetched-but-unconsumed batches are regenerated, not lost).
    """

    def __init__(self, corpus: np.ndarray, batch_size: int, seq_len: int,
                 seed: int = 0, prefetch: int = 4):
        self.corpus = np.asarray(corpus, np.int32)
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.seed = int(seed)
        self._prefetch = prefetch
        self._emitted = 0   # index of the next batch __next__ hands out
        self._start_thread()

    def _start_thread(self):
        self._q: queue.Queue = queue.Queue(maxsize=self._prefetch)
        self._stop = threading.Event()
        self._produced = self._emitted  # next index the thread generates
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _batch_at(self, index: int) -> dict:
        rng = np.random.default_rng([self.seed, index])
        n = len(self.corpus) - self.seq_len - 1
        starts = rng.integers(0, n, size=self.batch_size)
        toks = np.stack([self.corpus[s:s + self.seq_len + 1]
                         for s in starts])
        return {"tokens": toks}

    def _fill(self):
        while not self._stop.is_set():
            item = (self._produced, self._batch_at(self._produced))
            placed = False
            while not self._stop.is_set() and not placed:
                try:
                    self._q.put(item, timeout=0.5)
                    placed = True
                except queue.Full:
                    pass
            if placed:
                self._produced += 1

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        index, batch = self._q.get()
        self._emitted = index + 1
        return batch

    def _teardown(self):
        """Stop AND join the prefetch thread (a lingering thread would keep
        filling the dead queue), draining so a blocked put wakes up."""
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)

    def close(self):
        self._teardown()

    # -- SourceState protocol (via DataSource.state_dict) --------------------

    def state_dict(self) -> dict:
        return {"kind": type(self).__name__, "seed": self.seed,
                "offset": self._emitted}

    def load_state_dict(self, state: dict) -> None:
        if state.get("kind") != type(self).__name__:
            raise ValueError(
                f"iterator state is {state.get('kind')!r} but this run "
                f"built {type(self).__name__} — resume with the same data "
                "pipeline")
        self._teardown()
        self.seed = int(state["seed"])
        self._emitted = int(state["offset"])
        self._start_thread()


def rl_episode_batch(rng: np.random.Generator, batch_size: int, seq_len: int,
                     vocab_size: int, a: int = 5, b: int = 3) -> dict:
    """Random-behavior token-MDP episodes in the LLM-IMPALA batch layout
    (used to bootstrap training and for shape tests; the real driver
    generates these with the serving path)."""
    tokens = rng.integers(0, vocab_size,
                          size=(batch_size, seq_len + 1)).astype(np.int32)
    target = (a * tokens[:, :-1] + b) % vocab_size
    rewards = (tokens[:, 1:] == target).astype(np.float32)
    done = np.zeros((batch_size, seq_len), bool)
    done[:, -1] = True
    behavior_logprob = np.full((batch_size, seq_len),
                               -np.log(vocab_size), np.float32)
    return {"tokens": tokens, "behavior_logprob": behavior_logprob,
            "reward": rewards, "done": done}
