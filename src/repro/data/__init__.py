from repro.data.synthetic import PackedBatchIterator, markov_corpus, rl_episode_batch  # noqa: F401
