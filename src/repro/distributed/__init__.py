from repro.distributed.sharding import (EXPERT_RULES, FSDP_RULES,  # noqa: F401
                                        MEGATRON_RULES, RULE_SETS,
                                        SEQPAR_RULES, constrain,
                                        param_shardings, spec_for, use_rules,
                                        zero1_shardings)
