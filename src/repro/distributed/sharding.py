"""Logical-axis sharding (MaxText-style rules tables).

Every parameter carries logical axis names from init (models/common.param);
a *rules* dict maps logical -> mesh axes. Swapping rules is how the perf
hillclimb changes sharding without touching model code.

Activation constraints: model code calls ``constrain(x, logical_axes)``
which applies ``jax.lax.with_sharding_constraint`` when a (mesh, rules)
context is active, and is a no-op otherwise (CPU tests).

Rule sets provided:
  MEGATRON_RULES   — baseline: params over "model", batch over data axes,
                     optimizer state sharded like params.
  FSDP_RULES       — adds weight sharding over the data axes ("embed"->data)
  SEQPAR_RULES     — megatron + sequence-parallel residual stream
  EXPERT_RULES     — expert-parallel MoE (experts over "model")
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ctx = threading.local()

# "batch" below expands to all data-like mesh axes present (pod+data).
MEGATRON_RULES: Dict[str, object] = {
    "vocab": "model",
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "ssm_heads": "model",
    "conv_ch": "model",
    "act_batch": "batch",
    "act_seq": None,
    "act_embed": None,
    "act_vocab": "model",
    "expert": None,
}

FSDP_RULES = dict(MEGATRON_RULES, embed="batch")
SEQPAR_RULES = dict(MEGATRON_RULES, act_seq="model")
EXPERT_RULES = dict(MEGATRON_RULES, expert="model", mlp=None,
                    act_expert="model")

FSDP_SEQPAR_RULES = dict(MEGATRON_RULES, embed="batch", act_seq="model")
# context-parallel attention: keep q seq-sharded through attention instead
# of resharding to head-sharded each layer (saves the per-layer q
# all-gather when the residual stream is sequence-parallel) — §Perf H1.
CP_FSDP_SEQPAR_RULES = dict(FSDP_SEQPAR_RULES, attn_pref="seq")
EXPERT_SEQPAR_RULES = dict(SEQPAR_RULES, expert="model", mlp=None)

# RL-agent data parallelism: the convnet agents (models/convnet.py) are
# tiny, so every parameter axis is replicated and only the rollout batch is
# sharded over the data axes. Gradients of replicated params w.r.t. a
# data-sharded batch all-reduce automatically under sharding propagation —
# the data-parallel learner needs no explicit pmean.
RL_AGENT_RULES: Dict[str, object] = {
    "conv_h": None,
    "conv_w": None,
    "conv_in": None,
    "conv_out": None,
    "fc_in": None,
    "fc_out": None,
    "act_batch": "batch",
}

RULE_SETS = {
    "megatron": MEGATRON_RULES,
    "fsdp": FSDP_RULES,
    "seqpar": SEQPAR_RULES,
    "fsdp_seqpar": FSDP_SEQPAR_RULES,
    "cp_fsdp_seqpar": CP_FSDP_SEQPAR_RULES,
    "expert": EXPERT_RULES,
    "expert_seqpar": EXPERT_SEQPAR_RULES,
    "rl_agent": RL_AGENT_RULES,
}


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """All batch-like axes of the mesh ('pod' + 'data' when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data", "fsdp"))


def _resolve(rule, mesh: Mesh):
    if rule == "batch":
        axes = data_axes(mesh)
        return axes if len(axes) > 1 else (axes[0] if axes else None)
    return rule


def spec_for(logical_axes: Sequence[str], mesh: Mesh, rules: Dict,
             shape: Optional[Sequence[int]] = None,
             fallback_model: bool = False) -> P:
    """Map logical axes -> PartitionSpec, dropping non-divisible mappings.

    If ``shape`` is given, any mapping whose dimension is not divisible by
    the mesh-axis size is dropped (replicated) — this keeps one rules table
    valid across heterogeneous archs (e.g. kv_heads=8 on a 16-way model
    axis simply replicates).

    ``fallback_model``: if after the main pass the 'model' axis is unused
    (e.g. heads=56 on a 16-way axis), shard the largest still-replicated,
    divisible dimension over 'model' instead — parameters must never be
    fully replicated on the model axis (deepseek-coder's 56 heads would
    otherwise replicate the whole attention block).
    """
    used = set()
    parts = []
    for i, ax in enumerate(logical_axes):
        rule = _resolve(rules.get(ax), mesh)
        if rule is None:
            parts.append(None)
            continue
        mesh_axes = rule if isinstance(rule, tuple) else (rule,)
        mesh_axes = tuple(a for a in mesh_axes if a not in used)
        if not mesh_axes:
            parts.append(None)
            continue
        size = 1
        for a in mesh_axes:
            size *= mesh.shape[a]
        if shape is not None and shape[i] % size != 0:
            parts.append(None)
            continue
        used.update(mesh_axes)
        parts.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
    if (fallback_model and "model" not in used and shape is not None
            and "model" in mesh.shape):
        msize = mesh.shape["model"]
        cands = sorted(range(len(parts)), key=lambda i: -shape[i])
        for i in cands:
            if parts[i] is None and shape[i] % msize == 0 \
                    and shape[i] >= msize:
                parts[i] = "model"
                break
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def logical_to_mesh(logical_axes: Sequence[str], mesh: Mesh, rules: Dict,
                    shape: Optional[Sequence[int]] = None,
                    fallback_model: bool = False) -> P:
    """Public name for the logical-axes -> PartitionSpec mapping
    (MaxText's ``logical_to_mesh_axes`` analogue). The contract — pinned by
    tests/test_sharding_spec.py over every rules table — is that the
    returned spec only names live mesh axes, never repeats one, and (when
    ``shape`` is given) only maps dimensions the mesh-axis size divides.
    """
    return spec_for(logical_axes, mesh, rules, shape,
                    fallback_model=fallback_model)


def param_shardings(axes_tree, mesh: Mesh, rules: Dict, shapes_tree=None):
    """Tree of NamedSharding for a params tree (axes_tree from init)."""
    is_axes = lambda x: isinstance(x, tuple) and all(  # noqa: E731
        isinstance(a, str) for a in x)
    if shapes_tree is None:
        return jax.tree.map(
            lambda ax: NamedSharding(mesh, spec_for(ax, mesh, rules)),
            axes_tree, is_leaf=is_axes)
    return jax.tree.map(
        lambda ax, sh: NamedSharding(
            mesh, spec_for(ax, mesh, rules, sh.shape,
                           fallback_model=len(sh.shape) > 1)),
        axes_tree, shapes_tree, is_leaf=is_axes)


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Dict):
    prev = getattr(_ctx, "state", None)
    _ctx.state = (mesh, rules)
    try:
        yield
    finally:
        _ctx.state = prev


def current_rules():
    return getattr(_ctx, "state", None)


def constrain(x, logical_axes: Sequence[str]):
    """Apply a sharding constraint if a (mesh, rules) context is active."""
    state = current_rules()
    if state is None:
        return x
    mesh, rules = state
    spec = spec_for(logical_axes, mesh, rules, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def gather_seq(x):
    """Pin the sequence-parallel -> sequence-replicated reshard to THIS
    (bf16) tensor. Without it XLA gathers the fp32 norm intermediate —
    2x the wire bytes (EXPERIMENTS.md §Perf H1 iter-3). No-op unless the
    active rules shard act_seq."""
    state = current_rules()
    if state is None:
        return x
    mesh, rules = state
    if rules.get("act_seq") is None:
        return x
    daxes = data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    parts = [None] * x.ndim
    if daxes and x.shape[0] % dsize == 0:
        parts[0] = daxes if len(daxes) > 1 else daxes[0]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))


def constrain_attention(x, *, seq_dim=1, head_dim=2, batch_dim=0):
    """Sharding constraint for attention intermediates (q / expanded kv /
    outputs), shaped (B, S, H, hd).

    Batch always goes to the data axes. The model axis goes to HEADS when
    divisible (Megatron attention), else to the QUERY SEQUENCE (context-
    parallel fallback — required for e.g. deepseek-coder's 56 heads on a
    16-way axis, where neither H nor K divides). Without this constraint
    the GSPMD cost model has been observed to replicate the whole (B,H,S,S)
    score tensor (EXPERIMENTS.md §Perf).
    """
    state = current_rules()
    if state is None:
        return x
    mesh, rules = state
    daxes = data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    parts = [None] * x.ndim
    if daxes and x.shape[batch_dim] % dsize == 0:
        parts[batch_dim] = daxes if len(daxes) > 1 else daxes[0]
    if "model" in mesh.shape:
        msize = mesh.shape["model"]
        prefer_seq = rules.get("attn_pref") == "seq"
        seq_ok = (seq_dim >= 0 and x.shape[seq_dim] % msize == 0
                  and x.shape[seq_dim] >= msize)
        if prefer_seq and seq_ok:
            parts[seq_dim] = "model"
        elif x.shape[head_dim] % msize == 0:
            parts[head_dim] = "model"
        elif seq_ok:
            parts[seq_dim] = "model"
    while parts and parts[-1] is None:
        parts.pop()
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))


# ---------------------------------------------------------------------------
# ZeRO-1: shard optimizer state over the data axes on top of param sharding
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# Data-parallel rollout batches (the sharded IMPALA learner)
# ---------------------------------------------------------------------------

# Canonical time-major rollouts (core/sources.py) put the batch on dim 1
# (obs (T+1,B,...), action (T,B), ...); the exceptions are per-column
# vectors (is_replay (B,)) and recurrent core_state leaves ((B, hidden)).
_BATCH_DIM_OVERRIDES = {"is_replay": 0, "core_state": 0}


def batch_axes_spec(mesh: Mesh, rules: Dict, ndim: int, shape,
                    batch_dim: int) -> Optional[P]:
    """PartitionSpec sharding ``batch_dim`` over the data axes named by the
    rules' 'act_batch' entry (replicated when non-divisible / unmapped)."""
    rule = _resolve(rules.get("act_batch", "batch"), mesh)
    if rule is None:
        return None
    mesh_axes = rule if isinstance(rule, tuple) else (rule,)
    size = 1
    for a in mesh_axes:
        size *= mesh.shape[a]
    if size == 1 or shape[batch_dim] % size != 0:
        return None
    parts = [None] * ndim
    parts[batch_dim] = mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]
    return P(*parts)


def rollout_batch_shardings(mesh: Mesh, *, batch_dim: int = 1,
                            ndims: Sequence[int] = (2, 3, 4, 5, 6)):
    """ndim -> NamedSharding placing ``batch_dim`` over ALL mesh axes
    (replicated elsewhere) — the layout of a canonical time-major rollout
    batch fanned over a data mesh. One table shared by every producer of
    globally-sharded rollouts (ShardedDeviceSource per-device assembly,
    ShardedReplay sampled-column re-assembly, HostLoopSource learner-queue
    splitting), so their outputs compose without resharding."""
    axes = tuple(mesh.axis_names)
    ax = axes if len(axes) > 1 else axes[0]
    out = {}
    for nd in ndims:
        parts = [None] * nd
        parts[batch_dim] = ax
        out[nd] = NamedSharding(mesh, P(*parts))
    return out


def shard_rollout(batch, mesh: Mesh, rules: Dict):
    """Constrain every leaf of a canonical rollout batch to be sharded over
    the data axes on its batch dimension (replicated everywhere else).

    Inside a jitted learner step this pins the batch layout so gradient
    all-reduce falls out of sharding propagation; leaves whose batch size
    does not divide the data-axis size stay replicated.
    """

    def leaf(key, x):
        bd = _BATCH_DIM_OVERRIDES.get(key, 1 if jnp.ndim(x) >= 2 else 0)
        spec = batch_axes_spec(mesh, rules, jnp.ndim(x), jnp.shape(x), bd)
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    return {k: jax.tree.map(lambda x, k=k: leaf(k, x), v)
            for k, v in batch.items()}


def shard_lm_batch(batch, mesh: Mesh, rules: Dict):
    """Constrain every leaf of a BATCH-MAJOR LM token batch (tokens
    (B, S+1), behavior_logprob/reward/done (B, S), vision (B, Sv, d)) to
    shard its leading batch dimension over the data axes named by the
    rules' 'act_batch' entry (replicated when non-divisible).

    The model-axis sharding of parameters and activations comes from the
    rules table via ``param_shardings`` and the ``constrain()`` calls
    inside the model (active under ``use_rules``); this helper pins only
    the input layout, so the cross-data-axis gradient all-reduce falls out
    of sharding propagation exactly as in the rl-agent path
    (``shard_rollout``).
    """

    def leaf(x):
        spec = batch_axes_spec(mesh, rules, jnp.ndim(x), jnp.shape(x), 0)
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    return jax.tree.map(leaf, batch)


def tree_shardings(tree):
    """Tree of the CURRENT shardings of a (materialised) jax array tree —
    the ``shardings=`` argument checkpoint.restore needs to reassemble a
    sharded tree onto the live mesh (same-mesh or elastic resume)."""
    return jax.tree.map(lambda x: x.sharding, tree)


def replicate(tree, mesh: Mesh):
    """Constrain every leaf of ``tree`` to be fully replicated on ``mesh``
    (applied to grads in the sharded learner step: the constraint is where
    GSPMD materialises the cross-data-axis all-reduce)."""
    sharding = NamedSharding(mesh, P())
    return jax.tree.map(
        lambda x: jax.lax.with_sharding_constraint(x, sharding), tree)


def zero1_shardings(axes_tree, shapes_tree, mesh: Mesh, rules: Dict):
    """Optimizer-state shardings: like params, but each leaf additionally
    shards its first still-replicated, divisible dimension over the data
    axes (ZeRO-1). Falls back to the param sharding when nothing divides."""
    daxes = data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    spec_daxes = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)

    is_axes = lambda x: isinstance(x, tuple) and all(  # noqa: E731
        isinstance(a, str) for a in x)

    def one(ax, sh):
        base = spec_for(ax, mesh, rules, sh.shape,
                        fallback_model=len(sh.shape) > 1)
        parts = list(base) + [None] * (len(sh.shape) - len(base))
        used = set()
        for p in parts:
            for a in (p if isinstance(p, tuple) else (p,)):
                if a is not None:
                    used.add(a)
        if spec_daxes is not None and not used.intersection(daxes):
            for i, p in enumerate(parts):
                if p is None and sh.shape[i] % dsize == 0 and sh.shape[i] >= dsize:
                    parts[i] = spec_daxes
                    break
        while parts and parts[-1] is None:
            parts.pop()
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=is_axes)
