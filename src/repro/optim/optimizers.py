"""Pure-pytree optimizers (no optax in the container).

Each optimizer is a (init, update) pair:
  state = opt.init(params)
  updates, state = opt.update(grads, state, params, step)
  params = apply_updates(params, updates)

``rmsprop`` with IMPALA Table G.1 defaults (eps=0.01, decay=0.99) is the
paper-faithful learner optimizer; ``adamw`` is provided for the LLM-scale
drivers. Gradient clipping is global-norm (IMPALA: 40).
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params, step) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def _sched(lr, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def sgd(lr, momentum=0.0, grad_clip=None):
    def init(params):
        if momentum:
            return {"mom": jax.tree.map(
                lambda p: jnp.zeros_like(p, jnp.float32), params)}
        return {}

    def update(grads, state, params, step):
        del params
        if grad_clip:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        lr_t = _sched(lr, step)
        if momentum:
            mom = jax.tree.map(lambda m, g: momentum * m + g,
                               state["mom"], grads)
            return jax.tree.map(lambda m: -lr_t * m, mom), {"mom": mom}
        return jax.tree.map(lambda g: -lr_t * g, grads), state

    return Optimizer(init, update)


def rmsprop(lr, decay=0.99, eps=0.01, momentum=0.0, grad_clip=40.0):
    """TensorFlow-flavored RMSProp, as used by IMPALA/TorchBeast."""
    def init(params):
        st = {"ms": jax.tree.map(
            lambda p: jnp.zeros_like(p, jnp.float32), params)}
        if momentum:
            st["mom"] = jax.tree.map(
                lambda p: jnp.zeros_like(p, jnp.float32), params)
        return st

    def update(grads, state, params, step):
        del params
        if grad_clip:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        lr_t = _sched(lr, step)
        ms = jax.tree.map(lambda m, g: decay * m + (1 - decay) * g * g,
                          state["ms"], grads)
        scaled = jax.tree.map(
            lambda g, m: g * jax.lax.rsqrt(m + eps), grads, ms)
        if momentum:
            mom = jax.tree.map(lambda mo, s: momentum * mo + s,
                               state["mom"], scaled)
            return (jax.tree.map(lambda m: -lr_t * m, mom),
                    {"ms": ms, "mom": mom})
        return jax.tree.map(lambda s: -lr_t * s, scaled), {"ms": ms}

    return Optimizer(init, update)


def adamw(lr, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0, grad_clip=1.0):
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)  # noqa: E731
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        if grad_clip:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        lr_t = _sched(lr, step)
        t = step.astype(jnp.float32) + 1.0
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                          state["mu"], grads)
        nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * g * g,
                          state["nu"], grads)
        mu_hat = jax.tree.map(lambda m: m / (1 - b1 ** t), mu)
        nu_hat = jax.tree.map(lambda n: n / (1 - b2 ** t), nu)
        upd = jax.tree.map(
            lambda m, n, p: -lr_t * (m / (jnp.sqrt(n) + eps)
                                     + weight_decay * p.astype(jnp.float32)),
            mu_hat, nu_hat, params)
        return upd, {"mu": mu, "nu": nu}

    return Optimizer(init, update)


def make_optimizer(train_cfg):
    """Build the optimizer named in a TrainConfig (with its LR schedule)."""
    from repro.optim.schedules import make_schedule
    sched = make_schedule(train_cfg)
    if train_cfg.optimizer == "rmsprop":
        return rmsprop(sched, decay=train_cfg.rmsprop_decay,
                       eps=train_cfg.rmsprop_eps,
                       momentum=train_cfg.rmsprop_momentum,
                       grad_clip=train_cfg.grad_clip)
    if train_cfg.optimizer == "adamw":
        return adamw(sched, b1=train_cfg.adam_b1, b2=train_cfg.adam_b2,
                     eps=train_cfg.adam_eps,
                     weight_decay=train_cfg.weight_decay,
                     grad_clip=train_cfg.grad_clip)
    if train_cfg.optimizer == "sgd":
        return sgd(sched, grad_clip=train_cfg.grad_clip)
    raise ValueError(train_cfg.optimizer)
