"""Learning-rate schedules as step -> lr callables (traceable)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_anneal(lr, total_steps, warmup_steps=0):
    """IMPALA default: linear anneal to 0 over total_steps."""
    def f(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") \
            else jnp.asarray(step, jnp.float32)
        warm = jnp.where(warmup_steps > 0,
                         jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0),
                         1.0)
        frac = jnp.clip(1.0 - step / total_steps, 0.0, 1.0)
        return lr * warm * frac
    return f


def cosine(lr, total_steps, warmup_steps=0, min_ratio=0.1):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0) \
            if warmup_steps else 1.0
        t = jnp.clip((step - warmup_steps)
                     / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * warm * cos
    return f


def make_schedule(train_cfg):
    if train_cfg.lr_schedule == "linear":
        return linear_anneal(train_cfg.learning_rate, train_cfg.total_steps,
                             train_cfg.warmup_steps)
    if train_cfg.lr_schedule == "cosine":
        return cosine(train_cfg.learning_rate, train_cfg.total_steps,
                      train_cfg.warmup_steps)
    if train_cfg.lr_schedule == "constant":
        return constant(train_cfg.learning_rate)
    raise ValueError(train_cfg.lr_schedule)
