from repro.optim.optimizers import (adamw, apply_updates, clip_by_global_norm,  # noqa: F401
                                    global_norm, make_optimizer, rmsprop, sgd)
from repro.optim.schedules import constant, cosine, linear_anneal, make_schedule  # noqa: F401
