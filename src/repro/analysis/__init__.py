"""Static trace/kernel/concurrency auditor (``python -m repro.analysis``).

Gates the contracts CPU CI cannot execute: Pallas launch geometry and
VMEM budgets (``kernel_audit``), jit-cache/donation/sharding-axis
behavior (``trace_audit``), and thread-safety/host-sync discipline
(``concurrency_lint``). All three run by abstract evaluation or AST
inspection — no TPU, no FLOPs. Unwaived findings fail the CLI nonzero;
waive with an inline ``# analysis: ignore[rule]`` on the flagged line.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.analysis.common import Finding, apply_waivers
from repro.analysis.concurrency_lint import lint_tree
from repro.analysis.kernel_audit import (SMEM_BUDGET_BYTES,
                                         VMEM_BUDGET_BYTES, audit_kernels)
from repro.analysis.trace_audit import audit_traces

__all__ = ["Finding", "apply_waivers", "audit_kernels", "audit_traces",
           "lint_tree", "run_all", "SMEM_BUDGET_BYTES",
           "VMEM_BUDGET_BYTES"]


def run_all(*, vmem_budget: int = VMEM_BUDGET_BYTES,
            smem_budget: int = SMEM_BUDGET_BYTES,
            archs=None) -> Tuple[List[Finding], Dict]:
    """Run every analyzer; returns (waiver-resolved findings, report)."""
    from repro.kernels.compat import resolve_interpret

    kernel_findings, kernel_tables = audit_kernels(
        archs, vmem_budget=vmem_budget, smem_budget=smem_budget)
    trace_findings, trace_summaries = audit_traces(archs=archs)
    lint_findings = lint_tree()

    findings = apply_waivers(
        kernel_findings + trace_findings + lint_findings)
    unwaived = [f for f in findings if not f.waived]
    report = {
        "kernel_tables": kernel_tables,
        "trace_summaries": trace_summaries,
        "interpret_stats": resolve_interpret.stats(),
        "findings": [f.to_dict() for f in findings],
        "num_findings": len(findings),
        "num_unwaived": len(unwaived),
        "vmem_budget_bytes": vmem_budget,
        "smem_budget_bytes": smem_budget,
    }
    return findings, report
