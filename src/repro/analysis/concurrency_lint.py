"""AST concurrency lint over ``core/`` and ``launch/``.

Three rules, each targeting a failure mode the thread-based actor/server
machinery (``HostLoopSource``/``ActorPool``/``launch.serve``) can only
exhibit under load:

  * ``thread-shared-write`` — an attribute assigned inside a method
    reachable from a spawned thread's ``target=self.<m>`` callee chain,
    outside any ``with self.<lock>:`` block, while some *other* method of
    the class (outside that callee chain) reads it. That is a data race:
    the reader can observe torn/stale state. Writes and reads under a
    ``with self.<x>:`` context are treated as lock-guarded.
  * ``thread-no-join`` — a class stores started ``threading.Thread``s on
    ``self`` but no method ever calls ``.join`` — its stop path leaks the
    thread, which keeps running against freed state (the regression
    ``test_host_loop_stop_leaves_no_live_threads`` guards dynamically;
    this is the static version). Functions that *return* the thread they
    start hand ownership to the caller and are exempt.
  * ``host-sync`` — ``.item()`` / ``np.asarray`` / ``jax.device_get`` /
    ``block_until_ready`` inside a hot-path module. Each of these blocks
    the Python thread on device work and serializes the pipeline; they
    are only legal at the declared host API boundary (``HOT_ALLOWLIST``)
    or under an inline ``# analysis: ignore[host-sync]`` waiver.
"""

from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.common import REPO_SRC_ROOT, Finding

LINT_DIRS = ("src/repro/core", "src/repro/launch")

# modules where a host sync stalls the training/serving pipeline
HOT_MODULES = {
    "src/repro/core/learner.py",
    "src/repro/core/losses.py",
    "src/repro/core/vtrace.py",
    "src/repro/core/rollout.py",
    "src/repro/core/runtime.py",
    "src/repro/core/generate.py",
    "src/repro/launch/serve.py",
}

# the declared host API boundary: methods whose CONTRACT is to return
# host values (numpy out of DecodeSession, completed requests out of the
# Server). Qualified name -> rationale.
HOT_ALLOWLIST: Dict[str, str] = {
    "DecodeSession.prefill_into": "host API: returns numpy scalars",
    "DecodeSession.prefill_many": "host API: returns numpy scalars",
    "DecodeSession.step": "host API: returns numpy arrays",
    "Server.submit": "host API: validates/copies the incoming prompt",
    "Server._finish": "host API: materializes the finished request",
}

HOST_SYNC_ATTRS = {"item", "block_until_ready", "device_get"}
_NUMPY_MODULES = {"numpy"}
_JAX_MODULES = {"jax"}


def _attr_chain(node) -> List[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    parts.reverse()
    return parts


def _is_self_attr(node) -> Optional[str]:
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


class _ModuleAliases(ast.NodeVisitor):
    """import graph: local name -> top-level module ('np' -> 'numpy')."""

    def __init__(self):
        self.aliases: Dict[str, str] = {}

    def visit_Import(self, node):
        for a in node.names:
            self.aliases[a.asname or a.name.split(".")[0]] = \
                a.name.split(".")[0]

    def visit_ImportFrom(self, node):
        if node.module:
            top = node.module.split(".")[0]
            for a in node.names:
                self.aliases[a.asname or a.name] = f"{top}.{a.name}"


def _is_thread_ctor(call: ast.Call, aliases: Dict[str, str]) -> bool:
    chain = _attr_chain(call.func)
    if not chain:
        return False
    if chain[-1] != "Thread":
        return False
    root = aliases.get(chain[0], chain[0])
    return root.startswith("threading") or chain == ["Thread"]


# ---------------------------------------------------------------------------
# per-method facts
# ---------------------------------------------------------------------------

class _MethodFacts(ast.NodeVisitor):
    """Attribute reads/writes (lock-aware), self-calls, thread spawns."""

    def __init__(self, aliases: Dict[str, str]):
        self.aliases = aliases
        self.lock_depth = 0
        self.writes: Dict[str, Tuple[int, bool]] = {}   # attr -> (line, locked)
        self.reads: Dict[str, Tuple[int, bool]] = {}
        self.calls: Set[str] = set()                    # self.<m>() callees
        self.thread_targets: Set[str] = set()           # target=self.<m>
        self.spawned_attrs: Set[str] = set()            # self.<a> = Thread()
        self.spawns_local_returned = False
        self.has_join = False
        self._local_threads: Set[str] = set()
        self._returned: Set[str] = set()

    def visit_With(self, node):
        guards = any(_is_self_attr(i.context_expr) is not None
                     or (isinstance(i.context_expr, ast.Call)
                         and _is_self_attr(i.context_expr.func))
                     for i in node.items)
        if guards:
            self.lock_depth += 1
        self.generic_visit(node)
        if guards:
            self.lock_depth -= 1

    def _record_write(self, attr: str, line: int):
        prev = self.writes.get(attr)
        locked = self.lock_depth > 0
        if prev is None or (prev[1] and not locked):
            self.writes[attr] = (line, locked)

    def visit_Attribute(self, node):
        attr = _is_self_attr(node)
        if attr is not None:
            if isinstance(node.ctx, ast.Store):
                self._record_write(attr, node.lineno)
            elif isinstance(node.ctx, ast.Load):
                prev = self.reads.get(attr)
                locked = self.lock_depth > 0
                if prev is None or (prev[1] and not locked):
                    self.reads[attr] = (node.lineno, locked)
        self.generic_visit(node)

    def visit_AugAssign(self, node):
        attr = _is_self_attr(node.target)
        if attr is not None:
            self._record_write(attr, node.lineno)
            # an unlocked augmented assign is also an unlocked read
            prev = self.reads.get(attr)
            locked = self.lock_depth > 0
            if prev is None or (prev[1] and not locked):
                self.reads[attr] = (node.lineno, locked)
        self.generic_visit(node)

    def visit_Call(self, node):
        chain = _attr_chain(node.func)
        if len(chain) >= 2 and chain[0] == "self":
            self.calls.add(chain[1])
        if chain and chain[-1] == "join":
            # str.join takes exactly one positional iterable; thread join
            # takes none (or a timeout kwarg)
            if len(node.args) == 0:
                self.has_join = True
        if isinstance(node.func, (ast.Attribute, ast.Name)) \
                and _is_thread_ctor(node, self.aliases):
            for kw in node.keywords:
                if kw.arg == "target":
                    t = _is_self_attr(kw.value)
                    if t:
                        self.thread_targets.add(t)
        self.generic_visit(node)

    def visit_Assign(self, node):
        if isinstance(node.value, ast.Call) \
                and _is_thread_ctor(node.value, self.aliases):
            for tgt in node.targets:
                a = _is_self_attr(tgt)
                if a is not None:
                    self.spawned_attrs.add(a)
                elif isinstance(tgt, ast.Name):
                    self._local_threads.add(tgt.id)
        self.generic_visit(node)

    def visit_Return(self, node):
        if isinstance(node.value, ast.Name):
            self._returned.add(node.value.id)
        self.generic_visit(node)

    def finish(self):
        kept = self._local_threads - self._returned
        self.spawns_local_returned = bool(
            self._local_threads & self._returned)
        # local threads neither stored on self nor returned: treated as
        # fire-and-forget on the method — covered by thread-no-join only
        # if the class never joins anything
        self.spawned_attrs |= {f"<local:{n}>" for n in kept}


def _class_findings(path: str, cls: ast.ClassDef,
                    aliases: Dict[str, str]) -> List[Finding]:
    findings: List[Finding] = []
    methods: Dict[str, _MethodFacts] = {}
    for item in cls.body:
        if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
            facts = _MethodFacts(aliases)
            facts.visit(item)
            facts.finish()
            methods[item.name] = facts

    # transitive closure of methods reachable from any thread target
    roots = {t for f in methods.values() for t in f.thread_targets
             if t in methods}
    threaded: Set[str] = set()
    frontier = list(roots)
    while frontier:
        m = frontier.pop()
        if m in threaded:
            continue
        threaded.add(m)
        frontier.extend(c for c in methods[m].calls
                        if c in methods and c not in threaded)

    # rule: thread-shared-write
    for m in sorted(threaded):
        for attr, (line, locked) in methods[m].writes.items():
            if locked:
                continue
            for other, facts in methods.items():
                if other in threaded or other == "__init__":
                    continue
                read = facts.reads.get(attr)
                if read is not None and not read[1]:
                    findings.append(Finding(
                        rule="thread-shared-write", file=path, line=line,
                        message=(
                            f"{cls.name}.{m} writes self.{attr} on the "
                            f"spawned-thread path without a lock, while "
                            f"{cls.name}.{other} reads it (line "
                            f"{read[0]}) from outside that thread — "
                            "torn/stale reads under load")))
                    break

    # rule: thread-no-join
    spawns = {m: f.spawned_attrs for m, f in methods.items()
              if f.spawned_attrs}
    if spawns and not any(f.has_join for f in methods.values()):
        m, attrs = next(iter(sorted(spawns.items())))
        line = cls.lineno
        for item in cls.body:
            if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and item.name == m:
                line = item.lineno
        findings.append(Finding(
            rule="thread-no-join", file=path, line=line,
            message=(
                f"{cls.name}.{m} stores started thread(s) "
                f"({', '.join(sorted(attrs))}) but no method of "
                f"{cls.name} ever joins a thread — the stop path leaks "
                "a live thread running against freed state")))
    return findings


# ---------------------------------------------------------------------------
# host-sync rule
# ---------------------------------------------------------------------------

class _HostSyncVisitor(ast.NodeVisitor):
    def __init__(self, path: str, aliases: Dict[str, str]):
        self.path = path
        self.aliases = aliases
        self.scope: List[str] = []
        self.findings: List[Finding] = []

    def _qualname(self) -> str:
        return ".".join(self.scope)

    def _enter(self, node):
        self.scope.append(node.name)
        self.generic_visit(node)
        self.scope.pop()

    visit_ClassDef = _enter
    visit_FunctionDef = _enter
    visit_AsyncFunctionDef = _enter

    def _flag(self, node, what: str):
        qual = self._qualname()
        if qual in HOT_ALLOWLIST:
            return
        self.findings.append(Finding(
            rule="host-sync", file=self.path, line=node.lineno,
            message=(
                f"{what} in hot-path module "
                f"{os.path.basename(self.path)}"
                + (f" ({qual})" if qual else "")
                + " — blocks the Python thread on device transfer; move "
                "to the host API boundary or waive explicitly")))

    def visit_Call(self, node):
        chain = _attr_chain(node.func)
        if chain:
            root = self.aliases.get(chain[0], chain[0])
            last = chain[-1]
            if last == "item" and not node.args:
                self._flag(node, ".item() host sync")
            elif last == "block_until_ready":
                self._flag(node, "block_until_ready host sync")
            elif last == "device_get" and root.split(".")[0] in \
                    _JAX_MODULES:
                self._flag(node, "jax.device_get host sync")
            elif last == "asarray" and root.split(".")[0] in \
                    _NUMPY_MODULES:
                self._flag(node, "np.asarray device->host copy")
        self.generic_visit(node)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def lint_file(path: str, *, hot: Optional[bool] = None) -> List[Finding]:
    with open(path, encoding="utf-8") as f:
        source = f.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(rule="parse-error", file=path,
                        line=e.lineno or 0, message=str(e.msg))]
    imports = _ModuleAliases()
    imports.visit(tree)
    aliases = imports.aliases

    findings: List[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            findings.extend(_class_findings(path, node, aliases))

    rel = os.path.relpath(os.path.abspath(path), REPO_SRC_ROOT)
    if hot if hot is not None else rel.replace(os.sep, "/") in HOT_MODULES:
        hs = _HostSyncVisitor(path, aliases)
        hs.visit(tree)
        findings.extend(hs.findings)
    return findings


def lint_tree(root: str = REPO_SRC_ROOT) -> List[Finding]:
    findings: List[Finding] = []
    for d in LINT_DIRS:
        full = os.path.join(root, d)
        if not os.path.isdir(full):
            continue
        for name in sorted(os.listdir(full)):
            if name.endswith(".py"):
                findings.extend(lint_file(os.path.join(full, name)))
    return findings
