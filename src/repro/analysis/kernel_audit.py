"""Static Pallas kernel audit — the TPU contracts interpret-mode CI skips.

CPU CI executes every kernel in interpret mode, which checks the math but
not the launch geometry: an out-of-bounds ``BlockSpec`` index map, a block
shape that stops dividing the operand, or a VMEM working set past the
per-core budget all surface only on real hardware. This module verifies
them statically:

  * every kernel wrapper is abstract-evaluated (``jax.eval_shape``) with
    ``pl.pallas_call`` intercepted, so the audited grid / BlockSpecs /
    scratch shapes are the REAL ones the wrapper builds — nothing is
    mirrored by hand;
  * the grid is exhausted point by point and every index map evaluated
    with concrete integers (block-index convention: element offset =
    index * block dim), checking 0 <= offset and offset + block <= shape;
  * block shapes must divide the operand shape evenly — the invariant the
    kernels' ``assert``s and ``models/attention._divisor_block`` callers
    guarantee at runtime, re-proven here for the representative shapes;
  * the VMEM footprint is summed statically: input/output blocks counted
    TWICE (Pallas double-buffers the grid pipeline) plus scratch once,
    gated against a configurable budget (default 16 MB/v5e, per the note
    in ``kernels/flash_attention.py``). SMEM-resident operands/scratch are
    accounted separately against their own (much smaller) budget.

Each audited launch is joined with ``launch/roofline.py``'s analytic
``kernel_roofline`` numbers, so the report reads footprint and FLOPs side
by side per (kernel, arch, shape).
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import inspect
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.common import Finding
from repro.configs import ARCHS, get_config
from repro.launch.roofline import kernel_roofline

VMEM_BUDGET_BYTES = 16 * 1024 * 1024      # v5e per-core VMEM working budget
SMEM_BUDGET_BYTES = 256 * 1024            # scalar memory: small by design
GRID_LIMIT = 2_000_000                    # defensive cap on exhaustion

AUDIT_KERNELS = ("flash_attention", "decode_attention", "ssd_chunk",
                 "vtrace")


@dataclasses.dataclass
class KernelLaunch:
    """One captured ``pl.pallas_call`` launch, fully static."""
    kernel: str
    grid: Tuple[int, ...]
    in_specs: List[Any]                   # pl.BlockSpec per operand
    out_specs: List[Any]
    operands: List[jax.ShapeDtypeStruct]  # what the kernel was called with
    out_shapes: List[jax.ShapeDtypeStruct]
    scratch_shapes: Tuple[Any, ...]       # pltpu MemoryRefs
    file: str = ""
    line: int = 0
    operand_names: Optional[Sequence[str]] = None
    out_names: Optional[Sequence[str]] = None


@contextlib.contextmanager
def capture_launches(records: List[KernelLaunch], kernel_name: str,
                     file: str = "", line: int = 0):
    """Intercept ``pl.pallas_call``: record the launch, return abstract
    zeros of ``out_shape`` so the surrounding wrapper keeps tracing."""

    real = pl.pallas_call

    def fake(kernel, *, grid=None, in_specs=None, out_specs=None,
             out_shape=None, scratch_shapes=(), **_kw):
        def runner(*operands):
            outs_multi = isinstance(out_shape, (list, tuple))
            out_list = list(out_shape) if outs_multi else [out_shape]
            spec_list = (list(out_specs) if isinstance(out_specs,
                                                       (list, tuple))
                         else [out_specs])
            records.append(KernelLaunch(
                kernel=kernel_name,
                grid=(grid,) if isinstance(grid, int) else tuple(grid),
                in_specs=list(in_specs or []),
                out_specs=spec_list,
                operands=[jax.ShapeDtypeStruct(o.shape, o.dtype)
                          for o in operands],
                out_shapes=[jax.ShapeDtypeStruct(s.shape, s.dtype)
                            for s in out_list],
                scratch_shapes=tuple(scratch_shapes or ()),
                file=file, line=line))
            outs = [jnp.zeros(s.shape, s.dtype) for s in out_list]
            return outs if outs_multi else outs[0]
        return runner

    pl.pallas_call = fake
    try:
        yield
    finally:
        pl.pallas_call = real


def _space(ms) -> str:
    """'vmem' | 'smem' | 'any' from a pallas memory-space marker."""
    if ms is None:
        return "vmem"
    name = getattr(ms, "name", None) or str(ms)
    name = name.lower()
    if "smem" in name:
        return "smem"
    if "vmem" in name or "any" in name:
        return "vmem"
    return name


def _bytes(shape, dtype) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n * jnp.dtype(dtype).itemsize


def _iter_grid(grid: Tuple[int, ...]):
    idx = [0] * len(grid)
    total = 1
    for g in grid:
        total *= g
    for _ in range(total):
        yield tuple(idx)
        for d in range(len(grid) - 1, -1, -1):
            idx[d] += 1
            if idx[d] < grid[d]:
                break
            idx[d] = 0


def _audit_spec(launch: KernelLaunch, spec, operand, name: str,
                findings: List[Finding]) -> Dict:
    """Audit ONE (BlockSpec, operand) pair; returns its footprint row."""
    where = dict(file=launch.file, line=launch.line)
    space = _space(getattr(spec, "memory_space", None))
    block = getattr(spec, "block_shape", None)
    index_map = getattr(spec, "index_map", None)

    if block is None:               # whole operand resident (SMEM operands)
        return {"name": name, "space": space, "block_shape": None,
                "bytes": _bytes(operand.shape, operand.dtype)}

    block = tuple(operand.shape[d] if b is None else int(b)
                  for d, b in enumerate(block))
    if len(block) != len(operand.shape):
        findings.append(Finding(
            rule="kernel-block-rank", message=(
                f"{launch.kernel}/{name}: block rank {len(block)} != "
                f"operand rank {len(operand.shape)}"), **where))
        return {"name": name, "space": space, "block_shape": block,
                "bytes": _bytes(block, operand.dtype)}

    for d, (b, s) in enumerate(zip(block, operand.shape)):
        if b <= 0 or s % b != 0:
            findings.append(Finding(
                rule="kernel-block-divisibility", message=(
                    f"{launch.kernel}/{name}: block dim {d} is {b}, which "
                    f"does not divide operand dim {s} "
                    f"(shape {tuple(operand.shape)})"), **where))

    grid_points = 1
    for g in launch.grid:
        grid_points *= g
    if grid_points > GRID_LIMIT:
        findings.append(Finding(
            rule="kernel-grid-unaudited", message=(
                f"{launch.kernel}/{name}: grid {launch.grid} has "
                f"{grid_points} points (> {GRID_LIMIT}); index maps not "
                "exhausted — shrink the representative shape"), **where))
    elif index_map is not None:
        bad = 0
        for point in _iter_grid(launch.grid):
            idx = index_map(*point)
            idx = (idx,) if not isinstance(idx, tuple) else idx
            if len(idx) != len(block):
                findings.append(Finding(
                    rule="kernel-index-map-rank", message=(
                        f"{launch.kernel}/{name}: index map returned "
                        f"{len(idx)} indices for a rank-{len(block)} "
                        f"block at grid point {point}"), **where))
                break
            for d, (ix, b, s) in enumerate(zip(idx, block, operand.shape)):
                off = int(ix) * b
                if off < 0 or off + b > s:
                    bad += 1
                    if bad == 1:
                        findings.append(Finding(
                            rule="kernel-index-map-oob", message=(
                                f"{launch.kernel}/{name}: index map walks "
                                f"out of bounds at grid point {point}: "
                                f"dim {d} block index {int(ix)} covers "
                                f"elements [{off}, {off + b}) of a "
                                f"{s}-element axis"), **where))
            if bad:
                break               # one witness per spec is enough

    return {"name": name, "space": space, "block_shape": block,
            "bytes": _bytes(block, operand.dtype)}


def audit_launch(launch: KernelLaunch, *,
                 vmem_budget: int = VMEM_BUDGET_BYTES,
                 smem_budget: int = SMEM_BUDGET_BYTES,
                 ) -> Tuple[List[Finding], Dict]:
    """Audit one captured launch; returns (findings, footprint table)."""
    findings: List[Finding] = []
    where = dict(file=launch.file, line=launch.line)

    rows = []
    in_names = list(launch.operand_names or []) or [
        f"in{i}" for i in range(len(launch.operands))]
    for spec, op, name in zip(launch.in_specs, launch.operands, in_names):
        rows.append(dict(_audit_spec(launch, spec, op, name, findings),
                         kind="in"))
    out_names = list(launch.out_names or []) or [
        f"out{i}" for i in range(len(launch.out_shapes))]
    for spec, op, name in zip(launch.out_specs, launch.out_shapes,
                              out_names):
        rows.append(dict(_audit_spec(launch, spec, op, name, findings),
                         kind="out"))
    for i, ref in enumerate(launch.scratch_shapes):
        rows.append({"name": f"scratch{i}", "kind": "scratch",
                     "space": _space(getattr(ref, "memory_space", None)),
                     "block_shape": tuple(ref.shape),
                     "bytes": _bytes(ref.shape, ref.dtype)})

    block_vmem = sum(r["bytes"] for r in rows
                     if r["kind"] in ("in", "out") and r["space"] == "vmem")
    scratch_vmem = sum(r["bytes"] for r in rows
                       if r["kind"] == "scratch" and r["space"] == "vmem")
    smem = sum(r["bytes"] for r in rows if r["space"] == "smem")
    # double-buffered pipeline: in/out blocks are resident twice
    vmem_total = 2 * block_vmem + scratch_vmem

    if vmem_total > vmem_budget:
        findings.append(Finding(
            rule="kernel-vmem-budget", message=(
                f"{launch.kernel}: static VMEM footprint "
                f"{vmem_total / 2**20:.2f} MiB (2x{block_vmem} block + "
                f"{scratch_vmem} scratch bytes) exceeds the "
                f"{vmem_budget / 2**20:.0f} MiB budget"), **where))
    if smem > smem_budget:
        findings.append(Finding(
            rule="kernel-smem-budget", message=(
                f"{launch.kernel}: SMEM footprint {smem} B exceeds the "
                f"{smem_budget} B budget"), **where))

    table = {
        "kernel": launch.kernel,
        "grid": list(launch.grid),
        "operands": rows,
        "vmem_block_bytes": block_vmem,
        "vmem_scratch_bytes": scratch_vmem,
        "vmem_total_bytes": vmem_total,
        "smem_bytes": smem,
        "vmem_budget_bytes": vmem_budget,
        "ok": not findings,
    }
    return findings, table


# ---------------------------------------------------------------------------
# representative launches per (kernel, arch config)
# ---------------------------------------------------------------------------

AUDIT_BATCH = 2          # small batch keeps grids exhaustible; seq/head
                         # dims (what the block geometry depends on) are
                         # kept at representative scale


def _unwrapped(fn):
    return inspect.unwrap(fn)


def _src(fn):
    raw = _unwrapped(fn)
    return (inspect.getsourcefile(raw) or "",
            raw.__code__.co_firstlineno)


def _flash_cases(cfg):
    from repro.kernels import flash_attention as mod
    raw = _unwrapped(mod.flash_attention)
    file, line = _src(mod.flash_attention)
    attn_mods = [m for m, _ in cfg.block_pattern if m.endswith("attn")
                 and m != "xattn"]
    window = cfg.sliding_window if attn_mods and all(
        m in ("swa_attn", "local_attn") for m in attn_mods) else 0
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    for label, b, s in (("train_4k", 1, 4096), ("serve_1k", AUDIT_BATCH,
                                                1024)):
        q = jax.ShapeDtypeStruct((b, h, s, hd), dt)
        kv = jax.ShapeDtypeStruct((b, kh, s, hd), dt)
        fn = functools.partial(raw, causal=True, window=window,
                               interpret=False)
        yield {
            "kernel": "flash_attention", "shape": label,
            "call": (fn, (q, kv, kv)), "file": file, "line": line,
            "names": (("q", "k", "v"), ("o",)),
            "roofline": dict(dtype_bytes=dt.itemsize, b=b, h=h, kh=kh, s=s,
                             hd=hd, window=window, causal=True),
        }


def _decode_cases(cfg):
    from repro.kernels import decode_attention as mod
    raw = _unwrapped(mod.decode_attention)
    file, line = _src(mod.decode_attention)
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    b, s = AUDIT_BATCH * 2, 32768          # decode_32k cache capacity
    q = jax.ShapeDtypeStruct((b, h, hd), dt)
    kv = jax.ShapeDtypeStruct((b, kh, s, hd), dt)
    slot = jax.ShapeDtypeStruct((b, s), jnp.int32)
    pos = jax.ShapeDtypeStruct((b,), jnp.int32)
    fn = functools.partial(raw, interpret=False)
    yield {
        "kernel": "decode_attention", "shape": "decode_32k",
        "call": (fn, (q, kv, kv, slot, pos)), "file": file, "line": line,
        "names": (("pos", "q", "k", "v", "slot_pos"), ("o",)),
        "roofline": dict(dtype_bytes=dt.itemsize, b=b, h=h, kh=kh, s=s,
                         hd=hd),
    }


def _ssd_cases(cfg):
    from repro.kernels import ssd_chunk as mod
    raw = _unwrapped(mod.ssd_chunk)
    file, line = _src(mod.ssd_chunk)
    # archs without a mamba mixer are audited at canonical SSD dims so the
    # footprint table covers all four kernels for every config
    if cfg.ssm_state and cfg.ssm_head_dim:
        n, p = cfg.ssm_state, cfg.ssm_head_dim
        l = cfg.ssm_chunk
        nh = max(1, (cfg.ssm_expand * cfg.d_model) // cfg.ssm_head_dim)
        hot = any(m == "mamba" for m, _ in cfg.block_pattern)
    else:
        n, p, l, nh = 128, 64, 256, 32
        hot = False
    dt = jnp.dtype(cfg.dtype)
    bh = AUDIT_BATCH * nh
    c = jax.ShapeDtypeStruct((bh, l, n), dt)
    xdt = jax.ShapeDtypeStruct((bh, l, p), dt)
    da = jax.ShapeDtypeStruct((bh, l, 1), jnp.float32)
    h_prev = jax.ShapeDtypeStruct((bh, p, n), jnp.float32)
    fn = functools.partial(raw, interpret=False)
    yield {
        "kernel": "ssd_chunk", "shape": f"chunk_{l}",
        "call": (fn, (c, c, xdt, da, h_prev)), "file": file, "line": line,
        "names": (("c", "b", "xdt", "da", "h_prev"), ("y", "h_new")),
        "roofline": dict(dtype_bytes=4, bh=bh, l=l, n=n, p=p),
        "hot_path": hot,
    }


def _vtrace_cases(cfg):
    del cfg                                # shape is arch-independent
    from repro.kernels import vtrace as mod
    raw = _unwrapped(mod.vtrace_scan)
    file, line = _src(mod.vtrace_scan)
    t, b = 80, 1024                        # the paper's validation shape
    deltas = jax.ShapeDtypeStruct((t, b), jnp.float32)
    fn = functools.partial(raw, block_b=128, interpret=False)
    yield {
        "kernel": "vtrace", "shape": f"t{t}_b{b}",
        "call": (fn, (deltas, deltas)), "file": file, "line": line,
        "names": (("deltas", "dcs"), ("acc",)),
        "roofline": dict(t=t, b=b),
    }


_CASE_BUILDERS = {
    "flash_attention": _flash_cases,
    "decode_attention": _decode_cases,
    "ssd_chunk": _ssd_cases,
    "vtrace": _vtrace_cases,
}


def _has_attention(cfg) -> bool:
    mods = {m for m, _ in cfg.block_pattern}
    return bool(mods & {"attn", "local_attn", "swa_attn"}) \
        or bool(cfg.shared_attn_every)


def audit_kernels(archs: Optional[Sequence[str]] = None, *,
                  vmem_budget: int = VMEM_BUDGET_BYTES,
                  smem_budget: int = SMEM_BUDGET_BYTES,
                  ) -> Tuple[List[Finding], List[Dict]]:
    """Audit every Pallas kernel x registered arch x representative shape.

    Returns (findings, tables): one table row per audited launch, carrying
    the static footprint next to ``kernel_roofline``'s FLOP numbers.
    """
    findings: List[Finding] = []
    tables: List[Dict] = []
    for arch in archs or ARCHS:
        cfg = get_config(arch)
        for kernel in AUDIT_KERNELS:
            for case in _CASE_BUILDERS[kernel](cfg):
                fn, args = case["call"]
                records: List[KernelLaunch] = []
                with capture_launches(records, kernel,
                                      file=case["file"],
                                      line=case["line"]):
                    jax.eval_shape(fn, *args)
                if not records:
                    findings.append(Finding(
                        rule="kernel-no-launch", file=case["file"],
                        line=case["line"],
                        message=f"{kernel}[{arch}]: wrapper traced "
                                "without reaching pallas_call"))
                    continue
                for launch in records:
                    launch.operand_names, launch.out_names = case["names"]
                    fnd, table = audit_launch(
                        launch, vmem_budget=vmem_budget,
                        smem_budget=smem_budget)
                    findings.extend(fnd)
                    table.update(
                        arch=arch, shape=case["shape"],
                        hot_path=case.get(
                            "hot_path",
                            _has_attention(cfg) if "attention" in kernel
                            else True),
                        roofline=kernel_roofline(kernel,
                                                 **case["roofline"]))
                    tables.append(table)
    return findings, tables
