"""Static trace audit — jit-cache, donation, and sharding-axis contracts.

Every registered jitted entry point (the two LM step factories, the RL
``make_train_step``/``make_recurrent_train_step``, the decode session's
``_session_prefill``/``_session_step``, and the serving step) is
abstract-evaluated under ``jax.sharding.AbstractMesh`` + pure
``ShapeDtypeStruct``s — no devices, no FLOPs — and three contracts that
only misbehave at scale are checked statically:

  * **retrace hazard** — the entry is traced twice with *freshly
    constructed but equal* arguments (fresh structs, fresh configs from
    ``get_reduced_config``). Exactly one trace must happen; a second
    trace means some static argument hashes by identity (an
    ``__eq__``/``__hash__`` mismatch) and every caller pays a silent
    recompile per construction — the retrace storms the ROADMAP calls
    invisible on CPU CI.
  * **donation is real** — for every declared ``donate_argnums``, each
    donated leaf must find a (shape, dtype)-matching output leaf. A
    donated buffer with no matching output cannot be reused by XLA; the
    declaration silently does nothing and peak memory is double-counted.
  * **sharding axes are live** — every ``with_sharding_constraint``
    reached during the trace is intercepted and its PartitionSpec axis
    names checked against the mesh's axes (this subsumes
    ``test_sharding_spec.py``'s runtime checks as a static pass).

The module also asserts the ``session_fns`` compile cache is keyed by
config VALUE (two fresh-equal configs -> the same compiled fns object).
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.common import Finding

_SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class TraceEntry:
    """One jitted entry point under audit."""
    name: str
    fn: Callable                    # the UNJITTED callable
    make_args: Callable             # () -> (args, kwargs); fresh every call
    jit_kwargs: Dict[str, Any]      # static_argnames / donate_argnums
    mesh: Any = None                # mesh whose axes constraints may name
    file: str = ""
    line: int = 0


def _where(entry: TraceEntry) -> Dict:
    return dict(file=entry.file, line=entry.line)


def _loc(fn) -> Tuple[str, int]:
    code = getattr(fn, "__code__", None)
    if code is None:
        return "", 0
    return code.co_filename, code.co_firstlineno


def _spec_axes(spec) -> set:
    axes: set = set()
    for part in tuple(spec):
        if part is None:
            continue
        parts = part if isinstance(part, tuple) else (part,)
        axes.update(p for p in parts if isinstance(p, str))
    return axes


@contextlib.contextmanager
def _capture_constraints(records: List[Any]):
    """Intercept ``jax.lax.with_sharding_constraint`` (every sharding
    helper resolves the attribute at call time) and record shardings."""
    real = jax.lax.with_sharding_constraint

    def spy(x, shardings, *a, **kw):
        records.extend(jax.tree.leaves(
            shardings,
            is_leaf=lambda s: isinstance(
                s, (jax.sharding.Sharding, jax.sharding.PartitionSpec))))
        return real(x, shardings, *a, **kw)

    jax.lax.with_sharding_constraint = spy
    try:
        yield
    finally:
        jax.lax.with_sharding_constraint = real


def _leaf_sig(tree) -> List[Tuple]:
    return sorted((tuple(leaf.shape), str(jnp.dtype(leaf.dtype)))
                  for leaf in jax.tree.leaves(tree)
                  if hasattr(leaf, "shape"))


def audit_static_key(make_obj: Callable, name: str,
                     file: str = "", line: int = 0) -> List[Finding]:
    """Two fresh constructions must be equal AND hash-equal: anything used
    as a jit static argument (or compile-cache key) with ``__eq__`` but an
    identity ``__hash__`` forces one retrace per construction."""
    findings: List[Finding] = []
    a, b = make_obj(), make_obj()
    try:
        ha, hb = hash(a), hash(b)
    except TypeError:
        findings.append(Finding(
            rule="retrace-hazard", file=file, line=line,
            message=f"{name}: unhashable — cannot be a jit static "
                    "argument or compile-cache key"))
        return findings
    if a == b and ha != hb:
        findings.append(Finding(
            rule="retrace-hazard", file=file, line=line,
            message=f"{name}: __eq__/__hash__ mismatch — two equal "
                    "instances hash differently, so every fresh "
                    "construction forces a recompile"))
    return findings


def audit_entry(entry: TraceEntry) -> Tuple[List[Finding], Dict]:
    """Audit one entry: trace-once, donation, sharding axes."""
    findings: List[Finding] = []
    traces = {"n": 0}

    @functools.wraps(entry.fn)
    def counted(*a, **kw):
        traces["n"] += 1
        return entry.fn(*a, **kw)

    jitted = jax.jit(counted, **entry.jit_kwargs)
    constraints: List[Any] = []
    args, kwargs = entry.make_args()
    try:
        with _capture_constraints(constraints):
            out = jitted.eval_shape(*args, **kwargs)
        args2, kwargs2 = entry.make_args()
        jitted.eval_shape(*args2, **kwargs2)
    except ValueError as e:
        if "hashable" in str(e).lower():
            findings.append(Finding(
                rule="retrace-hazard", message=(
                    f"{entry.name}: static argument is unhashable "
                    f"({e})"), **_where(entry)))
            return findings, {"entry": entry.name, "error": str(e)}
        raise

    if traces["n"] != 1:
        findings.append(Finding(
            rule="retrace-hazard", message=(
                f"{entry.name}: {traces['n']} traces for two calls with "
                "freshly-constructed-but-equal arguments — a static "
                "argument is keyed by identity, every caller recompiles"),
            **_where(entry)))

    out_sig = _leaf_sig(out)
    dead = []
    for argnum in entry.jit_kwargs.get("donate_argnums", ()) or ():
        pool = list(out_sig)
        for sig in _leaf_sig(args[argnum]):
            if sig in pool:
                pool.remove(sig)
            else:
                dead.append((argnum, sig))
    if dead:
        argnums = sorted({d[0] for d in dead})
        findings.append(Finding(
            rule="donation-dead", message=(
                f"{entry.name}: donate_argnums={argnums} donate "
                f"{len(dead)} buffer(s) with no (shape, dtype)-matching "
                "output — XLA cannot reuse them, the donation is a "
                f"silent no-op (first: {dead[0][1]})"), **_where(entry)))

    allowed = set(getattr(entry.mesh, "axis_names", ()) or ())
    used: set = set()
    for s in constraints:
        spec = getattr(s, "spec", s)
        axes = _spec_axes(spec)
        used |= axes
        s_mesh = getattr(s, "mesh", None)
        # check against the entry's LIVE mesh when one is declared — a
        # NamedSharding built on some other (stale) mesh is exactly the
        # bug this catches; fall back to the sharding's own mesh
        mesh_axes = allowed or set(
            getattr(s_mesh, "axis_names", ()) or ())
        bad = axes - mesh_axes
        if bad:
            findings.append(Finding(
                rule="sharding-unknown-axis", message=(
                    f"{entry.name}: sharding constraint names axes "
                    f"{sorted(bad)} that are not live on the mesh "
                    f"(axes: {sorted(mesh_axes)})"), **_where(entry)))

    summary = {
        "entry": entry.name,
        "traces": traces["n"],
        "donated_argnums": list(
            entry.jit_kwargs.get("donate_argnums", ()) or ()),
        "constraint_axes": sorted(used),
        "num_constraints": len(constraints),
        "ok": not findings,
    }
    return findings, summary


# ---------------------------------------------------------------------------
# the registered entry points
# ---------------------------------------------------------------------------

T, B, S = 8, 4, 32      # unroll length / batch / LM sequence (reduced)


def _abstract(tree):
    return jax.tree.map(lambda x: _SDS(x.shape, x.dtype), tree)


def _train_cfg():
    from repro.configs.base import TrainConfig
    return TrainConfig(optimizer="adamw", learning_rate=1e-3,
                       grad_clip=1.0, lr_schedule="constant")


def _lm_pieces(arch: str):
    from repro.configs import get_reduced_config
    from repro.models import model as model_lib
    from repro.optim import make_optimizer
    cfg = get_reduced_config(arch)
    opt = make_optimizer(_train_cfg())
    params = jax.eval_shape(
        lambda: model_lib.init(jax.random.PRNGKey(0), cfg)[0])
    opt_state = jax.eval_shape(opt.init, params)
    return cfg, opt, params, opt_state


def _lm_batch():
    return {"tokens": _SDS((B, S + 1), jnp.int32),
            "behavior_logprob": _SDS((B, S), jnp.float32),
            "reward": _SDS((B, S), jnp.float32),
            "done": _SDS((B, S), jnp.bool_)}


def _rl_pieces(recurrent: bool):
    from repro.core import rollout as rollout_lib
    from repro.envs import catch
    from repro.models.convnet import (init_agent, minatar_lstm_net,
                                      minatar_net)
    env = catch.make()
    if recurrent:
        init_fn, apply_fn, init_state = minatar_lstm_net(env.obs_shape,
                                                         env.num_actions)
        unroll = rollout_lib.make_recurrent_unroll(env, apply_fn,
                                                   init_state, T)
    else:
        init_fn, apply_fn = minatar_net(env.obs_shape, env.num_actions)
        unroll = rollout_lib.make_unroll(env, apply_fn, T)
    params = jax.eval_shape(
        lambda: init_agent(init_fn, jax.random.PRNGKey(0))[0])
    key = jax.random.PRNGKey(1)
    env_state, obs = rollout_lib.env_reset_batch(env, key, B)
    carry = (unroll.initial_carry(env_state, obs, B) if recurrent
             else (env_state, obs))
    rollout = jax.eval_shape(unroll, params, _abstract(carry),
                             _SDS((2,), jnp.uint32))[1]
    return apply_fn, params, rollout


def _session_pieces(arch: str, batch: int, cache_len: int):
    from repro.configs import get_reduced_config
    from repro.core.generate import _session_prefill
    from repro.models import model as model_lib
    cfg = get_reduced_config(arch)
    params = jax.eval_shape(
        lambda: model_lib.init(jax.random.PRNGKey(0), cfg)[0])
    prompt = _SDS((batch, 8), jnp.int32)
    keys = _SDS((batch, 2), jnp.uint32)
    temp = _SDS((batch,), jnp.float32)
    state = jax.eval_shape(
        functools.partial(_session_prefill, cfg=cfg,
                          cache_seq_len=cache_len),
        params, prompt, keys, temp)[0]
    return cfg, params, (prompt, keys, temp), state


def registered_entries(mesh=None) -> List[TraceEntry]:
    """Every jitted entry point the platform ships, as audit entries.

    ``mesh`` (default: a 2x2 AbstractMesh over (data, model)) scopes the
    LM factories; RL and session entries run unmeshed, exactly like the
    single-host paths.
    """
    from repro.configs import get_reduced_config
    from repro.core import generate as gen_lib
    from repro.core import learner as learner_lib
    from repro.distributed import sharding as shd
    from repro.optim import make_optimizer

    if mesh is None:
        mesh = jax.sharding.AbstractMesh((("data", 2), ("model", 2)))
    rules = shd.MEGATRON_RULES
    entries: List[TraceEntry] = []
    step_sds = _SDS((), jnp.int32)

    # -- LM step factories (2-D mesh path) ---------------------------------
    cfg, opt, params, opt_state = _lm_pieces("qwen3-4b")
    lm_rl = learner_lib.make_lm_train_step(
        cfg, opt, _train_cfg(), loss_chunk=S, mesh=mesh, rules=rules)
    file, line = _loc(lm_rl)
    entries.append(TraceEntry(
        name="make_lm_train_step[qwen3-4b]", fn=lm_rl,
        make_args=lambda: ((params, opt_state, step_sds, _lm_batch()), {}),
        jit_kwargs={"donate_argnums": (0, 1)}, mesh=mesh,
        file=file, line=line))

    lm_pre = learner_lib.make_lm_pretrain_step(
        cfg, opt, loss_chunk=S, mesh=mesh, rules=rules)
    file, line = _loc(lm_pre)
    entries.append(TraceEntry(
        name="make_lm_pretrain_step[qwen3-4b]", fn=lm_pre,
        make_args=lambda: ((params, opt_state, step_sds,
                            {"tokens": _SDS((B, S + 1), jnp.int32)}), {}),
        jit_kwargs={"donate_argnums": (0, 1)}, mesh=mesh,
        file=file, line=line))

    # -- RL learner steps ---------------------------------------------------
    tc = _train_cfg()
    apply_fn, rl_params, rollout = _rl_pieces(recurrent=False)
    rl_opt = make_optimizer(tc)
    rl_opt_state = jax.eval_shape(rl_opt.init, rl_params)
    rl_step = learner_lib.make_train_step(apply_fn, rl_opt, tc)
    file, line = _loc(rl_step)
    entries.append(TraceEntry(
        name="make_train_step[catch]", fn=rl_step,
        make_args=lambda: ((rl_params, rl_opt_state, step_sds,
                            dict(rollout)), {}),
        jit_kwargs={"donate_argnums": (0, 1)}, file=file, line=line))

    r_apply, r_params, r_rollout = _rl_pieces(recurrent=True)
    r_opt_state = jax.eval_shape(rl_opt.init, r_params)
    rec_step = learner_lib.make_recurrent_train_step(r_apply, rl_opt, tc)
    file, line = _loc(rec_step)
    entries.append(TraceEntry(
        name="make_recurrent_train_step[catch]", fn=rec_step,
        make_args=lambda: ((r_params, r_opt_state, step_sds,
                            dict(r_rollout)), {}),
        jit_kwargs={"donate_argnums": (0, 1)}, file=file, line=line))

    # -- decode session + serving step --------------------------------------
    # configs are STATIC arguments here, constructed fresh per call: this
    # is the direct fresh-equal-config retrace check.
    arch = "qwen3-4b"
    _, s_params, prefill_args, state = _session_pieces(arch, B, 64)
    file, line = _loc(gen_lib._session_prefill)
    entries.append(TraceEntry(
        name=f"_session_prefill[{arch}]", fn=gen_lib._session_prefill,
        make_args=lambda: ((s_params,) + prefill_args,
                           {"cfg": get_reduced_config(arch),
                            "cache_seq_len": 64}),
        jit_kwargs={"static_argnames": ("cfg", "cache_seq_len")},
        file=file, line=line))
    file, line = _loc(gen_lib._session_step)
    entries.append(TraceEntry(
        name=f"_session_step[{arch}]", fn=gen_lib._session_step,
        make_args=lambda: ((s_params, dict(state)),
                           {"cfg": get_reduced_config(arch)}),
        jit_kwargs={"static_argnames": ("cfg",),
                    "donate_argnums": (1,)},
        file=file, line=line))

    # serving shape: a Server's max_batch-row session (the hot loop of
    # launch/serve.py is exactly this step, donated state included)
    _, sv_params, _, sv_state = _session_pieces(arch, 8, 128)
    entries.append(TraceEntry(
        name="serve_step[max_batch=8]", fn=gen_lib._session_step,
        make_args=lambda: ((sv_params, dict(sv_state)),
                           {"cfg": get_reduced_config(arch)}),
        jit_kwargs={"static_argnames": ("cfg",),
                    "donate_argnums": (1,)},
        file=file, line=line))
    return entries


def audit_traces(mesh=None, archs: Optional[Sequence[str]] = None,
                 ) -> Tuple[List[Finding], List[Dict]]:
    """Run the full static trace audit. Returns (findings, summaries)."""
    from repro.configs import ARCHS, get_reduced_config
    from repro.core.generate import session_fns

    findings: List[Finding] = []
    summaries: List[Dict] = []

    # every registered config must be a well-behaved compile-cache key
    from repro.configs import base as cfg_base
    cfg_file = cfg_base.__file__
    for arch in archs or ARCHS:
        findings.extend(audit_static_key(
            lambda arch=arch: get_reduced_config(arch),
            f"ModelConfig[{arch}]", file=cfg_file, line=0))

    # session-fns compile cache must key by config value, not identity
    from repro.core import generate as gen_lib
    a = session_fns(get_reduced_config("qwen3-4b"))
    b = session_fns(get_reduced_config("qwen3-4b"))
    if a is not b:
        findings.append(Finding(
            rule="retrace-hazard", file=gen_lib.__file__, line=0,
            message="session_fns: two freshly-constructed equal configs "
                    "resolve to different compiled fns — the cache keys "
                    "by identity and every actor/server recompiles"))

    for entry in registered_entries(mesh):
        fnd, summary = audit_entry(entry)
        findings.extend(fnd)
        summaries.append(summary)
    return findings, summaries
