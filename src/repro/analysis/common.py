"""Shared finding/waiver infrastructure for the static analyzers.

Every analyzer emits ``Finding`` records anchored to a (file, line). A
finding is waived when the anchored line — or the line directly above it —
carries an inline waiver comment naming its rule:

    x = np.asarray(y)   # analysis: ignore[host-sync]

Waivers are resolved once over the final finding list (``apply_waivers``),
so analyzers stay pure emitters; the CLI exits nonzero only on unwaived
findings.
"""

from __future__ import annotations

import dataclasses
import os
import re
from typing import Dict, List, Optional

REPO_SRC_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))

_WAIVER_RE = re.compile(r"#\s*analysis:\s*ignore\[([A-Za-z0-9_\-, ]+)\]")


@dataclasses.dataclass
class Finding:
    rule: str          # e.g. "kernel-vmem-budget", "thread-shared-write"
    file: str          # path (made repo-relative in reports when possible)
    line: int          # 1-indexed anchor line (0 = whole-file/abstract)
    message: str
    waived: bool = False

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        mark = " (waived)" if self.waived else ""
        return f"{self.file}:{self.line}: [{self.rule}]{mark} {self.message}"


def relpath(path: str) -> str:
    try:
        rel = os.path.relpath(os.path.abspath(path), REPO_SRC_ROOT)
    except ValueError:            # different drive (windows); keep absolute
        return path
    return path if rel.startswith("..") else rel


def waived_rules(source_lines: List[str], line: int) -> set:
    """Rules waived at ``line`` (1-indexed): inline or on the line above."""
    rules: set = set()
    for ln in (line, line - 1):
        if 1 <= ln <= len(source_lines):
            m = _WAIVER_RE.search(source_lines[ln - 1])
            if m:
                rules.update(r.strip() for r in m.group(1).split(",")
                             if r.strip())
    return rules


class _SourceCache:
    def __init__(self):
        self._cache: Dict[str, Optional[List[str]]] = {}

    def lines(self, path: str) -> Optional[List[str]]:
        if path not in self._cache:
            try:
                with open(path, encoding="utf-8") as f:
                    self._cache[path] = f.read().splitlines()
            except OSError:
                self._cache[path] = None
        return self._cache[path]


def apply_waivers(findings: List[Finding]) -> List[Finding]:
    """Mark findings whose anchor line carries a matching inline waiver."""
    cache = _SourceCache()
    for f in findings:
        if not f.file or f.line <= 0:
            continue
        lines = cache.lines(f.file if os.path.isabs(f.file)
                            else os.path.join(REPO_SRC_ROOT, f.file))
        if lines is None:
            continue
        if f.rule in waived_rules(lines, f.line):
            f.waived = True
    for f in findings:
        f.file = relpath(f.file)
    return findings
