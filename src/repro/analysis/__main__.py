"""CLI: ``python -m repro.analysis --report analysis_report.json``.

Exits 0 iff no unwaived findings; the JSON report carries the per-kernel
VMEM footprint tables (joined with roofline FLOPs), the per-entry trace
summaries, and every finding (waived ones included, marked)."""

from __future__ import annotations

import argparse
import json
import sys


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static trace/kernel/concurrency audit")
    parser.add_argument("--report", default=None, metavar="PATH",
                        help="write the full JSON report here")
    parser.add_argument("--vmem-budget-mb", type=float, default=16.0,
                        help="per-core VMEM budget (default 16 MB/v5e)")
    parser.add_argument("--smem-budget-kb", type=float, default=256.0,
                        help="SMEM budget (default 256 KB)")
    parser.add_argument("--archs", default=None,
                        help="comma-separated arch subset (default: all)")
    args = parser.parse_args(argv)

    from repro.analysis import run_all

    findings, report = run_all(
        vmem_budget=int(args.vmem_budget_mb * 1024 * 1024),
        smem_budget=int(args.smem_budget_kb * 1024),
        archs=args.archs.split(",") if args.archs else None)

    print(f"kernel launches audited: {len(report['kernel_tables'])}")
    for row in report["kernel_tables"]:
        print(f"  {row['kernel']:<16} {row['arch']:<14} "
              f"{row['shape']:<10} grid={tuple(row['grid'])!s:<16} "
              f"vmem={row['vmem_total_bytes'] / 2**20:6.2f} MiB  "
              f"smem={row['smem_bytes']:>5} B  "
              f"flops={row['roofline']['flops']:.3g}")
    print(f"trace entries audited: {len(report['trace_summaries'])}")
    for row in report["trace_summaries"]:
        axes = ",".join(row.get("constraint_axes", [])) or "-"
        print(f"  {row['entry']:<34} traces={row.get('traces', '?')} "
              f"donated={row.get('donated_argnums', [])} axes={axes}")
    stats = report["interpret_stats"]
    if stats.get("fallbacks"):
        print(f"interpret fallbacks this run: {stats['fallbacks']}")

    if args.report:
        with open(args.report, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"report written to {args.report}")

    waived = [f for f in findings if f.waived]
    unwaived = [f for f in findings if not f.waived]
    for f in waived:
        print(f"WAIVED  {f}")
    for f in unwaived:
        print(f"FAIL    {f}")
    print(f"{len(unwaived)} unwaived finding(s), {len(waived)} waived")
    return 1 if unwaived else 0


if __name__ == "__main__":
    sys.exit(main())
