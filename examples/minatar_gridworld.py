"""The paper's canonical adaptation (Figs. 1-2): swap the environment to a
MinAtar-style task and the agent to the small MinAtar ConvNet — two changes,
exactly as TorchBeast prescribes.

  PYTHONPATH=src python examples/minatar_gridworld.py [--steps 800]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.atari_impala import small_train
from repro.core import learner as learner_lib
from repro.core import rollout as rollout_lib
from repro.envs import gridworld  # <- the create_env swap (Fig. 1)
from repro.models.convnet import init_agent, minatar_net  # <- Fig. 2 model
from repro.optim import make_optimizer


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=800)
    args = p.parse_args()

    env = gridworld.make()
    train_cfg = small_train(unroll_length=20, batch_size=32,
                            learning_rate=1e-3,
                            total_steps=args.steps + 1000)
    init_fn, apply_fn = minatar_net(env.obs_shape, env.num_actions)
    params, _ = init_agent(init_fn, jax.random.PRNGKey(0))
    opt = make_optimizer(train_cfg)
    opt_state = opt.init(params)

    key = jax.random.PRNGKey(1)
    carry = rollout_lib.env_reset_batch(env, key, train_cfg.batch_size)
    unroll = rollout_lib.make_unroll(env, apply_fn, train_cfg.unroll_length)
    train_step = learner_lib.make_train_step(apply_fn, opt, train_cfg)

    @jax.jit
    def combined(params, opt_state, step, carry, key):
        carry, ro = unroll(params, carry, key)
        params, opt_state, m = train_step(params, opt_state, step, ro)
        return params, opt_state, carry, m

    t0 = time.time()
    for step in range(args.steps):
        key, k = jax.random.split(key)
        params, opt_state, carry, m = combined(
            params, opt_state, jnp.int32(step), carry, k)
        if step % max(1, args.steps // 15) == 0 or step == args.steps - 1:
            fps = (step + 1) * 32 * 20 / (time.time() - t0)
            print(f"step {step:5d} reward/step="
                  f"{float(m['reward_per_step']):+.3f} fps={fps:.0f}")


if __name__ == "__main__":
    main()
