"""Quickstart: MonoBeast-style IMPALA on Catch, end to end, on CPU —
both actor architectures running through the same unified ``Runtime``
(core/runtime.py):

  1. ``HostLoopSource`` — actor threads + DynamicBatcher (inference queue)
     + BatchingQueue (learner queue): the paper's MonoBeast/PolyBeast
     design, for envs that cannot be compiled;
  2. ``DeviceSource`` — the on-device compiled rollout (the TPU-native
     adaptation) with double-buffered dispatch, for the actual training
     run — reward reaches the optimum (+0.1/step) in ~1 minute on CPU.

Off-policy replay (core/replay.py) composes over either source:
``--replay {uniform,elite,attentive}`` mixes ``--replay-ratio`` replayed
rollouts into every learner batch (stored behavior logits keep V-trace
correct; CLEAR cloning terms regularise the replayed rows).

  PYTHONPATH=src python examples/quickstart.py
  PYTHONPATH=src python examples/quickstart.py --replay elite \
      --replay-ratio 1.0 --steps 800
"""

import argparse
import dataclasses

import jax

from repro.configs.atari_impala import small_train
from repro.core import learner as learner_lib
from repro.core import replay as replay_lib
from repro.core.runtime import Runtime
from repro.core.sources import DeviceSource, HostLoopSource, ReplaySource
from repro.envs import catch
from repro.models.convnet import init_agent, minatar_net
from repro.optim import make_optimizer


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=1500,
                   help="on-device training steps")
    p.add_argument("--replay", default="off",
                   choices=["off", "uniform", "elite", "attentive"])
    p.add_argument("--replay-capacity", type=int, default=512)
    p.add_argument("--replay-ratio", type=float, default=1.0,
                   help="replayed:fresh columns per batch (1.0 = 1:1)")
    args = p.parse_args()

    env = catch.make()
    train_cfg = small_train(unroll_length=20, batch_size=32,
                            learning_rate=2e-3, total_steps=2500)
    if args.replay != "off":
        train_cfg = dataclasses.replace(train_cfg, clear_policy_cost=0.01,
                                        clear_value_cost=0.005)
    init_fn, apply_fn = minatar_net(env.obs_shape, env.num_actions)
    params, _ = init_agent(init_fn, jax.random.PRNGKey(0))
    opt = make_optimizer(train_cfg)
    train_step = jax.jit(learner_lib.make_train_step(apply_fn, opt,
                                                     train_cfg))

    # --- 1. host loop smoke: actors -> inference queue -> learner queue ---
    print("== host-loop (MonoBeast) actors: a few learner steps ==")
    host = HostLoopSource(env, apply_fn, num_actors=8,
                          unroll_length=train_cfg.unroll_length,
                          batch_size=8)
    Runtime(host, train_step, params, opt.init(params), total_steps=3,
            log_every=1, log_keys=("reward_per_step", "loss")).run()

    # --- 2. on-device training to convergence (double-buffered) ---
    print(f"== on-device (compiled, double-buffered) IMPALA training "
          f"(replay={args.replay}) ==")
    source = DeviceSource.for_env(
        env, apply_fn, unroll_length=train_cfg.unroll_length,
        batch_size=train_cfg.batch_size, key=jax.random.PRNGKey(1),
        pipelined=True)
    if args.replay != "off":
        source = ReplaySource(
            source, replay_lib.make_buffer(args.replay,
                                           args.replay_capacity),
            replay_ratio=args.replay_ratio,
            value_fn=jax.jit(lambda p, obs: apply_fn(p, obs).baseline))
    runtime = Runtime(source, train_step, params, opt.init(params),
                      total_steps=args.steps, log_every=max(args.steps // 10,
                                                            1),
                      log_keys=("reward_per_step",))
    runtime.run()
    final = float(runtime.metrics["reward_per_step"])
    print(f"done: reward/step={final:+.3f} (optimal +0.100) "
          f"({'SOLVED' if final > 0.05 else 'not solved'})")


if __name__ == "__main__":
    main()
