"""Quickstart: MonoBeast-style IMPALA on Catch, end to end, on CPU.

Runs BOTH actor architectures against the same learner:
  1. the host loop (actor threads + DynamicBatcher + BatchingQueue) — the
     paper's MonoBeast/PolyBeast design, for envs that cannot be compiled;
  2. the on-device compiled rollout (the TPU-native adaptation) for the
     actual training run — reward reaches the optimum (+0.1/step) in
     ~1 minute on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs.atari_impala import small_train
from repro.core import learner as learner_lib
from repro.core import rollout as rollout_lib
from repro.core.actor_pool import ActorPool, start_inference_thread
from repro.core.batcher import BatchingQueue, DynamicBatcher
from repro.envs import catch
from repro.envs.base import HostEnv
from repro.models.convnet import init_agent, minatar_net
from repro.optim import make_optimizer


def main():
    env = catch.make()
    train_cfg = small_train(unroll_length=20, batch_size=32,
                            learning_rate=2e-3, total_steps=2500)
    init_fn, apply_fn = minatar_net(env.obs_shape, env.num_actions)
    params, _ = init_agent(init_fn, jax.random.PRNGKey(0))
    opt = make_optimizer(train_cfg)
    opt_state = opt.init(params)

    # --- 1. host loop smoke: actors -> inference queue -> learner queue ---
    print("== host-loop (MonoBeast) actors: one learner batch ==")
    policy = jax.jit(lambda obs: apply_fn(params, obs).policy_logits)
    inference = DynamicBatcher(max_batch_size=8, timeout_ms=5)
    learner_queue = BatchingQueue(8, batch_dim=1)
    pool = ActorPool(lambda seed: HostEnv(env, seed), num_actors=8,
                     unroll_length=train_cfg.unroll_length,
                     inference=inference, learner_queue=learner_queue)
    start_inference_thread(inference, lambda o: policy(jnp.asarray(o)))
    pool.start()
    batch = learner_queue.get(timeout=60)
    print("learner batch:", {k: v.shape for k, v in batch.items()})
    pool.stop()

    # --- 2. on-device training to convergence ---
    print("== on-device (compiled) IMPALA training ==")
    key = jax.random.PRNGKey(1)
    carry = rollout_lib.env_reset_batch(env, key, train_cfg.batch_size)
    unroll = rollout_lib.make_unroll(env, apply_fn, train_cfg.unroll_length)
    train_step = learner_lib.make_train_step(apply_fn, opt, train_cfg)

    @jax.jit
    def combined(params, opt_state, step, carry, key):
        carry, ro = unroll(params, carry, key)
        params, opt_state, m = train_step(params, opt_state, step, ro)
        return params, opt_state, carry, m

    t0 = time.time()
    frames = 0
    for step in range(1500):
        key, k = jax.random.split(key)
        params, opt_state, carry, m = combined(
            params, opt_state, jnp.int32(step), carry, k)
        frames += train_cfg.batch_size * train_cfg.unroll_length
        if step % 150 == 0 or step == 1499:
            print(f"step {step:5d} reward/step="
                  f"{float(m['reward_per_step']):+.3f} "
                  f"(optimal +0.100) fps={frames/(time.time()-t0):.0f}")
    final = float(m["reward_per_step"])
    print(f"done: reward/step={final:+.3f} "
          f"({'SOLVED' if final > 0.05 else 'not solved'})")


if __name__ == "__main__":
    main()
