"""Quickstart: MonoBeast-style IMPALA on Catch, end to end, on CPU —
both actor architectures running through the same unified ``Runtime``
(core/runtime.py):

  1. ``HostLoopSource`` — actor threads + DynamicBatcher (inference queue)
     + BatchingQueue (learner queue): the paper's MonoBeast/PolyBeast
     design, for envs that cannot be compiled;
  2. ``DeviceSource`` — the on-device compiled rollout (the TPU-native
     adaptation) with double-buffered dispatch, for the actual training
     run — reward reaches the optimum (+0.1/step) in ~1 minute on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.configs.atari_impala import small_train
from repro.core import learner as learner_lib
from repro.core.runtime import Runtime
from repro.core.sources import DeviceSource, HostLoopSource
from repro.envs import catch
from repro.models.convnet import init_agent, minatar_net
from repro.optim import make_optimizer


def main():
    env = catch.make()
    train_cfg = small_train(unroll_length=20, batch_size=32,
                            learning_rate=2e-3, total_steps=2500)
    init_fn, apply_fn = minatar_net(env.obs_shape, env.num_actions)
    params, _ = init_agent(init_fn, jax.random.PRNGKey(0))
    opt = make_optimizer(train_cfg)
    train_step = jax.jit(learner_lib.make_train_step(apply_fn, opt,
                                                     train_cfg))

    # --- 1. host loop smoke: actors -> inference queue -> learner queue ---
    print("== host-loop (MonoBeast) actors: a few learner steps ==")
    host = HostLoopSource(env, apply_fn, num_actors=8,
                          unroll_length=train_cfg.unroll_length,
                          batch_size=8)
    Runtime(host, train_step, params, opt.init(params), total_steps=3,
            log_every=1, log_keys=("reward_per_step", "loss")).run()

    # --- 2. on-device training to convergence (double-buffered) ---
    print("== on-device (compiled, double-buffered) IMPALA training ==")
    source = DeviceSource.for_env(
        env, apply_fn, unroll_length=train_cfg.unroll_length,
        batch_size=train_cfg.batch_size, key=jax.random.PRNGKey(1),
        pipelined=True)
    runtime = Runtime(source, train_step, params, opt.init(params),
                      total_steps=1500, log_every=150,
                      log_keys=("reward_per_step",))
    runtime.run()
    final = float(runtime.metrics["reward_per_step"])
    print(f"done: reward/step={final:+.3f} (optimal +0.100) "
          f"({'SOLVED' if final > 0.05 else 'not solved'})")


if __name__ == "__main__":
    main()
