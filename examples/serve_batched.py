"""DEPRECATED: fixed-batch serving was replaced by the continuous-batching
server in ``repro.launch.serve`` (DecodeSession + request handles).

This wrapper is kept so existing invocations keep working — it forwards to
the new server (``--policy static`` reproduces the old drain-a-batch
scheduling). Prefer:

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced

See README "Serving" and tests/test_decode_session.py for the new API.
"""

import sys
import warnings

from repro.launch.serve import main

if __name__ == "__main__":
    warnings.warn(
        "examples/serve_batched.py is deprecated; use "
        "`python -m repro.launch.serve` (continuous batching) instead",
        DeprecationWarning, stacklevel=1)
    argv = sys.argv[1:]
    if "--reduced" not in argv:
        argv.append("--reduced")
    main(argv)
