"""Batched serving with the PolyBeast inference queue: concurrent request
threads -> DynamicBatcher -> compiled prefill+decode -> scattered replies.

  PYTHONPATH=src python examples/serve_batched.py --arch mixtral-8x7b
(always uses the reduced config on CPU; pick any of the 10 archs)
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--reduced" not in argv:
        argv.append("--reduced")
    main(argv)
