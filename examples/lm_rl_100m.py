"""End-to-end driver: IMPALA-train a ~100M-parameter decoder policy on the
token-MDP for a few hundred steps (the LLM-scale instantiation of the
TorchBeast architecture, DESIGN.md §2).

The policy is a qwen3-family decoder scaled to ~100M params. Actors =
compiled generate() (behavior log-probs recorded); learner = V-trace +
policy gradient on the generated episodes. Reward = fraction of tokens
matching the hidden affine chain; a learning policy climbs from 1/V
(~0.001) toward 1.0.

  PYTHONPATH=src python examples/lm_rl_100m.py --steps 300
Measured run (vocab 256): reward/step 0.003 (random) -> 0.50 by step 80.
(defaults are sized so a CPU run finishes in tens of minutes; use
 --d-model 256 --layers 4 --steps 60 for a quick look)
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.core import generate as gen_lib
from repro.core import learner as learner_lib
from repro.models import model as model_lib
from repro.optim import make_optimizer


def make_100m_config(d_model, layers, vocab):
    """qwen3-family block at ~100M params (d=512, 12L, V=8192 -> ~47M body
    + embeddings; d=640/16L pushes ~100M)."""
    base = get_config("qwen3-4b")
    return dataclasses.replace(
        base, name="qwen3-100m", d_model=d_model, num_heads=8,
        num_kv_heads=4, head_dim=64, d_ff=4 * d_model, vocab_size=vocab,
        num_groups=layers, attn_chunk=256, ssm_chunk=64,
        dtype="float32", remat=False, tie_embeddings=True)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--batch", type=int, default=32)
    p.add_argument("--ep-len", type=int, default=32)
    p.add_argument("--d-model", type=int, default=640)
    p.add_argument("--layers", type=int, default=16)
    p.add_argument("--vocab", type=int, default=512,
                   help="small vocab keeps random-hit reward discoverable "
                        "(1/V per token); 512 learns in ~100 steps")
    p.add_argument("--lr", type=float, default=3e-4)
    args = p.parse_args()

    cfg = make_100m_config(args.d_model, args.layers, args.vocab)
    print(f"policy: {cfg.name} ~{cfg.param_count()/1e6:.0f}M params")
    tc = TrainConfig(optimizer="adamw", learning_rate=args.lr,
                     grad_clip=1.0, lr_schedule="constant",
                     entropy_cost=0.002, baseline_cost=0.5,
                     total_steps=args.steps)
    params, _ = model_lib.init(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer(tc)
    opt_state = opt.init(params)
    train_step = jax.jit(learner_lib.make_lm_train_step(
        cfg, opt, tc, loss_chunk=args.ep_len))

    a_mod, b_mod = 5, 3
    key = jax.random.PRNGKey(7)
    t0 = time.time()
    for step in range(args.steps):
        key, kgen, kprompt = jax.random.split(key, 3)
        prompt = jax.random.randint(kprompt, (args.batch, 1), 0,
                                    cfg.vocab_size)
        ep = gen_lib.generate(params, prompt, kgen, cfg=cfg,
                              num_steps=args.ep_len)
        tokens = ep["tokens"]
        target = (a_mod * tokens[:, :-1] + b_mod) % cfg.vocab_size
        reward = (tokens[:, 1:] == target).astype(jnp.float32)
        done = jnp.zeros_like(reward, bool).at[:, -1].set(True)
        batch = {"tokens": tokens, "behavior_logprob": ep["logprob"],
                 "reward": reward, "done": done}
        params, opt_state, m = train_step(params, opt_state,
                                          jnp.int32(step), batch)
        if step % max(1, args.steps // 25) == 0 or step == args.steps - 1:
            toks = (step + 1) * args.batch * args.ep_len
            print(f"step {step:4d} reward/step="
                  f"{float(m['reward_per_step']):.4f} "
                  f"H={-float(m['entropy_loss'])/args.ep_len:.2f} "
                  f"tok/s={toks/(time.time()-t0):.0f}")


if __name__ == "__main__":
    main()
