"""V-trace off-policy-correction ablation (the paper's §2 motivation,
quantified): actors run a LAGGED copy of the policy (as they do in any
asynchronous IMPALA deployment — ``DeviceSource(param_sync_every=lag)``);
the learner either

  * corrected   — V-trace with the true behavior logits (TorchBeast), or
  * uncorrected — pretends the data is on-policy (rho forced to 1).

With no lag both match A2C; with lag the uncorrected learner trains on a
biased policy-gradient. Results are recorded in EXPERIMENTS.md §Validation.

  PYTHONPATH=src python examples/vtrace_ablation.py [--steps 700 --lag 10]
"""

import argparse

import jax
import numpy as np

from repro.configs.atari_impala import small_train
from repro.core import learner as learner_lib
from repro.core.runtime import Runtime
from repro.core.sources import DeviceSource
from repro.envs import catch
from repro.models.convnet import init_agent, minatar_net
from repro.optim import make_optimizer


def run(corrected: bool, lag: int, steps: int, seed: int = 0,
        lr: float = 2e-3):
    env = catch.make()
    tc = small_train(unroll_length=20, batch_size=32, learning_rate=lr,
                     total_steps=steps + 1000)
    init_fn, apply_fn = minatar_net(env.obs_shape, env.num_actions)
    params, _ = init_agent(init_fn, jax.random.PRNGKey(seed))
    opt = make_optimizer(tc)

    # actor weight sync every `lag` learner steps (lag 0 -> every step)
    source = DeviceSource.for_env(
        env, apply_fn, unroll_length=tc.unroll_length,
        batch_size=tc.batch_size, key=jax.random.PRNGKey(seed + 1),
        pipelined=False, param_sync_every=max(1, lag))
    train_step = learner_lib.make_train_step(apply_fn, opt, tc)

    @jax.jit
    def uncorrected_step(params, opt_state, step, batch):
        """Overwrite behavior logits with the learner's own — the
        'uncorrected' arm (rho == 1 identically)."""
        out = apply_fn(params, batch["obs"][:-1])
        batch = dict(batch, behavior_logits=jax.lax.stop_gradient(
            out.policy_logits))
        return train_step(params, opt_state, step, batch)

    step_fn = jax.jit(train_step) if corrected else uncorrected_step
    rewards = []
    Runtime(source, step_fn, params, opt.init(params), total_steps=steps,
            log_every=0,
            on_metrics=lambda s, m: rewards.append(
                float(m["reward_per_step"]))).run()
    return np.mean(rewards[-100:])


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=700)
    p.add_argument("--lag", type=int, default=40)
    p.add_argument("--lr", type=float, default=5e-3)
    p.add_argument("--seeds", type=int, default=3)
    args = p.parse_args()

    print(f"arm,lag,mean_final_reward_over_{args.seeds}_seeds "
          f"(optimal +0.100)")
    for corrected in (True, False):
        for lag in (0, args.lag):
            rs = [run(corrected, lag, args.steps, seed=s, lr=args.lr)
                  for s in range(args.seeds)]
            arm = "vtrace" if corrected else "uncorrected"
            print(f"{arm},{lag},{np.mean(rs):+.3f} (min {min(rs):+.3f})",
                  flush=True)


if __name__ == "__main__":
    main()
