"""V-trace correctness: reference equality, IMPALA-paper properties, and
the Pallas kernel path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.vtrace import (vtrace_from_importance_weights,
                               vtrace_from_logits)
from repro.kernels import ops


def ref_vtrace(log_rhos, discounts, rewards, values, bootstrap,
               rho_clip=1.0, c_clip=1.0):
    T, B = log_rhos.shape
    rhos = np.exp(log_rhos)
    crho = np.minimum(rho_clip, rhos)
    cs = np.minimum(c_clip, rhos)
    vtp1 = np.concatenate([values[1:], bootstrap[None]], 0)
    deltas = crho * (rewards + discounts * vtp1 - values)
    vs = np.zeros_like(values)
    acc = np.zeros(B, np.float64)
    for t in reversed(range(T)):
        acc = deltas[t] + discounts[t] * cs[t] * acc
        vs[t] = values[t] + acc
    vs_tp1 = np.concatenate([vs[1:], bootstrap[None]], 0)
    pg = crho * (rewards + discounts * vs_tp1 - values)
    return vs, pg


def _rand(rng, t, b):
    return (rng.normal(0, 1, (t, b)).astype(np.float32),
            (rng.random((t, b)) > 0.2).astype(np.float32) * 0.97,
            rng.normal(0, 1, (t, b)).astype(np.float32),
            rng.normal(0, 1, (t, b)).astype(np.float32),
            rng.normal(0, 1, (b,)).astype(np.float32))


def test_matches_reference():
    rng = np.random.default_rng(0)
    args = _rand(rng, 13, 9)
    vs_r, pg_r = ref_vtrace(*args)
    out = vtrace_from_importance_weights(*map(jnp.asarray, args))
    np.testing.assert_allclose(out.vs, vs_r, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(out.pg_advantages, pg_r, rtol=2e-5, atol=2e-5)


def test_on_policy_reduces_to_discounted_returns():
    """IMPALA §4.1: with rho == c == 1 (on-policy), vs is the n-step
    bootstrapped return."""
    rng = np.random.default_rng(1)
    _, disc, rew, val, boot = _rand(rng, 17, 5)
    lr = np.zeros((17, 5), np.float32)
    out = vtrace_from_importance_weights(lr, disc, rew, val, boot)
    ret = boot.copy()
    rets = np.zeros_like(val)
    for t in reversed(range(17)):
        ret = rew[t] + disc[t] * ret
        rets[t] = ret
    np.testing.assert_allclose(out.vs, rets, rtol=2e-5, atol=2e-5)


def test_zero_discount_gives_one_step():
    """With gamma = 0, vs_t = V_t + rho_t (r_t - V_t) exactly."""
    rng = np.random.default_rng(2)
    lr, _, rew, val, boot = _rand(rng, 7, 3)
    disc = np.zeros_like(rew)
    out = vtrace_from_importance_weights(lr, disc, rew, val, boot)
    crho = np.minimum(1.0, np.exp(lr))
    np.testing.assert_allclose(out.vs, val + crho * (rew - val),
                               rtol=2e-5, atol=2e-5)


# Seeded sweep standing in for the former hypothesis property test, so the
# suite runs on a bare install (hypothesis is an optional extra).
@pytest.mark.parametrize("t,b,seed,rho_clip", [
    (2, 1, 0, 0.5), (5, 8, 17, 1.0), (13, 3, 2**10, 2.5),
    (30, 8, 2**16, 4.0), (21, 5, 40961, 0.75), (9, 2, 31337, 3.2)])
def test_clipping_property(t, b, seed, rho_clip):
    """vs is bounded when rhos explode (the point of the clipping), and
    increasing clip only changes vs where rho exceeds it."""
    rng = np.random.default_rng(seed)
    lr, disc, rew, val, boot = _rand(rng, t, b)
    lr = lr * 5.0  # extreme off-policiness
    out = vtrace_from_importance_weights(
        jnp.asarray(lr), jnp.asarray(disc), jnp.asarray(rew),
        jnp.asarray(val), jnp.asarray(boot),
        clip_rho_threshold=rho_clip, clip_c_threshold=1.0)
    assert np.isfinite(np.asarray(out.vs)).all()
    bound = np.abs(val).max() + rho_clip * (
        np.abs(rew) + 0.97 * (np.abs(val).max() + np.abs(boot).max())
        + np.abs(val).max()).max() * t
    assert np.abs(np.asarray(out.vs)).max() <= bound


def test_from_logits_matches_manual_logprobs():
    rng = np.random.default_rng(3)
    t, b, a = 9, 4, 6
    bl = rng.normal(0, 1, (t, b, a)).astype(np.float32)
    tl = rng.normal(0, 1, (t, b, a)).astype(np.float32)
    actions = rng.integers(0, a, (t, b))
    _, disc, rew, val, boot = _rand(rng, t, b)
    out = vtrace_from_logits(jnp.asarray(bl), jnp.asarray(tl),
                             jnp.asarray(actions), jnp.asarray(disc),
                             jnp.asarray(rew), jnp.asarray(val),
                             jnp.asarray(boot))

    def lp(logits):
        x = logits - logits.max(-1, keepdims=True)
        x = x - np.log(np.exp(x).sum(-1, keepdims=True))
        return np.take_along_axis(x, actions[..., None], -1)[..., 0]

    vs_r, pg_r = ref_vtrace(lp(tl) - lp(bl), disc, rew, val, boot)
    np.testing.assert_allclose(out.vs, vs_r, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(out.pg_advantages, pg_r, rtol=2e-4, atol=2e-4)


def test_kernel_path_matches_scan():
    rng = np.random.default_rng(4)
    args = _rand(rng, 23, 64)
    a = vtrace_from_importance_weights(*map(jnp.asarray, args))
    b = ops.vtrace_from_importance_weights_kernel(*map(jnp.asarray, args))
    np.testing.assert_allclose(a.vs, b.vs, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(a.pg_advantages, b.pg_advantages,
                               rtol=1e-6, atol=1e-6)


def test_targets_carry_no_gradient():
    rng = np.random.default_rng(5)
    args = _rand(rng, 5, 3)

    def f(values):
        out = vtrace_from_importance_weights(
            jnp.asarray(args[0]), jnp.asarray(args[1]), jnp.asarray(args[2]),
            values, jnp.asarray(args[4]))
        return jnp.sum(out.vs) + jnp.sum(out.pg_advantages)

    g = jax.grad(f)(jnp.asarray(args[3]))
    np.testing.assert_allclose(g, np.zeros_like(args[3]))
