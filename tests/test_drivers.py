"""Driver/CLI smoke tests: train.py modes, serve.py server, the IMPALA deep
ResNet, and checkpoint emission from the drivers."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np


def test_impala_deep_resnet_forward():
    """The paper's 'deep network' (15-conv ResNet) at Atari input shape."""
    from repro.configs.atari_impala import NUM_ACTIONS, OBS_SHAPE
    from repro.models.convnet import impala_deep, init_agent
    init_fn, apply_fn = impala_deep(OBS_SHAPE, NUM_ACTIONS)
    params, axes = init_agent(init_fn, jax.random.PRNGKey(0))
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    assert 1e6 < n < 3e6  # ~1.2M params, as in IMPALA-deep w/o LSTM
    obs = jax.random.uniform(jax.random.PRNGKey(1), (5, 3) + OBS_SHAPE)
    out = jax.jit(apply_fn)(params, obs)
    assert out.policy_logits.shape == (5, 3, NUM_ACTIONS)
    assert out.baseline.shape == (5, 3)
    assert bool(jnp.isfinite(out.policy_logits).all())


def test_train_cli_rl_agent(capsys):
    from repro.launch import train as T
    with tempfile.TemporaryDirectory() as d:
        T.main(["--mode", "rl-agent", "--env", "catch", "--steps", "6",
                "--batch", "8", "--checkpoint-dir", d])
        assert os.path.exists(os.path.join(d, "step_6", "manifest.json"))
    out = capsys.readouterr().out
    assert "reward/step" in out


def test_train_cli_lm(capsys):
    from repro.launch import train as T
    T.main(["--mode", "lm", "--arch", "xlstm-125m", "--reduced",
            "--steps", "4", "--batch", "4", "--seq", "16"])
    out = capsys.readouterr().out
    assert "loss=" in out


def test_train_cli_lm_rl(capsys):
    from repro.launch import train as T
    T.main(["--mode", "lm-rl", "--arch", "qwen3-4b", "--reduced",
            "--steps", "3", "--batch", "4", "--seq", "16"])
    out = capsys.readouterr().out
    assert "reward/step" in out


def test_serve_server_roundtrip():
    """Request-handle API: concurrent submits with per-request budgets all
    complete, echo their prompts, and respect max_tokens/stop gating."""
    from repro.configs import get_reduced_config
    from repro.launch.serve import Server
    from repro.models import model as M
    cfg = get_reduced_config("xlstm-125m")
    params, _ = M.init(jax.random.PRNGKey(0), cfg)
    server = Server(cfg, params, max_batch=4, max_len=16,
                    default_max_tokens=6).start()
    try:
        rng = np.random.default_rng(0)
        prompts = rng.integers(0, cfg.vocab_size, size=(5, 7))
        handles = [server.submit(prompts[i],
                                 max_tokens=3 + i % 3,
                                 temperature=0.5 + 0.25 * i)
                   for i in range(5)]
        results = [h.result(timeout=120) for h in handles]
        for i, (h, r) in enumerate(zip(handles, results)):
            assert h.done()
            assert r.shape == (7 + 3 + i % 3,)
            np.testing.assert_array_equal(r[:7], prompts[i])
            assert h.t_done >= h.t_first >= h.t_submit
        assert server.served == 5
    finally:
        server.stop()


def test_checkpoint_restore_resumes_training():
    """Save params+opt mid-run, restore, and verify identical next step."""
    from repro import checkpoint as ckpt
    from repro.configs import get_reduced_config
    from repro.configs.base import TrainConfig
    from repro.core import learner as L
    from repro.models import model as M
    from repro.optim import make_optimizer
    cfg = get_reduced_config("xlstm-125m")
    tc = TrainConfig(optimizer="adamw", learning_rate=1e-3, grad_clip=1.0,
                     lr_schedule="constant")
    params, _ = M.init(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer(tc)
    opt_state = opt.init(params)
    step = jax.jit(L.make_lm_pretrain_step(cfg, opt, loss_chunk=16))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0,
                                          cfg.vocab_size)}
    params, opt_state, _ = step(params, opt_state, jnp.int32(0), batch)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "step_1")
        ckpt.save(path, {"params": params, "opt": opt_state}, {"step": 1})
        restored, meta = ckpt.restore(path, {"params": params,
                                             "opt": opt_state})
    p2a, _, m_a = step(params, opt_state, jnp.int32(1), batch)
    p2b, _, m_b = step(jax.tree.map(jnp.asarray, restored["params"]),
                       jax.tree.map(jnp.asarray, restored["opt"]),
                       jnp.int32(1), batch)
    np.testing.assert_allclose(float(m_a["loss"]), float(m_b["loss"]),
                               rtol=1e-6)
