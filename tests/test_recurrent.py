"""Mamba2 (chunked SSD) and xLSTM correctness: chunked-parallel forms vs
sequential decode recurrence; state continuation across prefill/decode."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced_config
from repro.models import mamba, xlstm
from repro.models.common import split_params


def _zamba_cfg(**over):
    return dataclasses.replace(get_reduced_config("zamba2-2.7b"), **over)


def _xlstm_cfg(**over):
    return dataclasses.replace(get_reduced_config("xlstm-125m"), **over)


def test_mamba_chunked_matches_sequential():
    cfg = _zamba_cfg(ssm_chunk=8)
    params = split_params(mamba.mamba_init(jax.random.PRNGKey(0), cfg))[0]
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y_chunk, st = mamba.mamba_apply(params, x, cfg, return_state=True)

    cache = mamba.mamba_cache_init(cfg, 2, x.dtype)
    ys = []
    for t in range(32):
        y, cache = mamba.mamba_decode(params, x[:, t:t + 1], cache, cfg)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_chunk, y_seq, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st["ssm"], cache["ssm"], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st["conv"], cache["conv"], rtol=1e-5,
                               atol=1e-5)


def test_mamba_chunk_size_invariance():
    params = split_params(
        mamba.mamba_init(jax.random.PRNGKey(0), _zamba_cfg()))[0]
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(2), (1, 64, 256))
    outs = [mamba.mamba_apply(params, x, _zamba_cfg(ssm_chunk=c))[0]
            for c in (8, 16, 64)]
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-4, atol=2e-4)


def test_mlstm_chunked_matches_sequential_reference():
    cfg = _xlstm_cfg(xlstm_chunk=8)
    params = split_params(xlstm.mlstm_init(jax.random.PRNGKey(0), cfg))[0]
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(3), (2, 24, cfg.d_model))
    y_chunk, st = xlstm.mlstm_apply(params, x, cfg, return_state=True)
    y_ref, st_ref = xlstm.mlstm_reference(params, x, cfg)
    np.testing.assert_allclose(y_chunk, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st["C"], st_ref["C"], rtol=2e-4, atol=2e-4)


def test_mlstm_forget_gate_decays_state():
    """With very negative forget pre-activations, old state must not leak:
    generated output at step t should depend ~only on recent inputs."""
    cfg = _xlstm_cfg(xlstm_chunk=4)
    params = split_params(xlstm.mlstm_init(jax.random.PRNGKey(0), cfg))[0]
    params = dict(params, bf=jnp.full_like(params["bf"], -20.0))
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 16, cfg.d_model))
    x2 = x.at[:, :8].set(jax.random.normal(jax.random.PRNGKey(5),
                                           (1, 8, cfg.d_model)))
    y1, _ = xlstm.mlstm_apply(params, x, cfg)
    y2, _ = xlstm.mlstm_apply(params, x2, cfg)
    np.testing.assert_allclose(y1[:, -1], y2[:, -1], rtol=1e-3, atol=1e-3)


def test_slstm_apply_matches_decode_loop():
    cfg = _xlstm_cfg()
    params = split_params(xlstm.slstm_init(jax.random.PRNGKey(0), cfg))[0]
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(6), (2, 12, cfg.d_model))
    y_full, st = xlstm.slstm_apply(params, x, cfg, return_state=True)
    state = xlstm.slstm_state_init(cfg, 2)
    ys = []
    for t in range(12):
        y, state = xlstm.slstm_decode(params, x[:, t:t + 1], state, cfg)
        ys.append(y)
    np.testing.assert_allclose(y_full, jnp.concatenate(ys, 1), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(st["h"], state["h"], rtol=2e-4, atol=2e-4)


def test_mamba_state_continuation():
    """apply(x1) then apply(x2, state) == apply(concat(x1,x2))."""
    cfg = _zamba_cfg(ssm_chunk=8)
    params = split_params(mamba.mamba_init(jax.random.PRNGKey(0), cfg))[0]
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(7), (1, 32, cfg.d_model))
    y_full, _ = mamba.mamba_apply(params, x, cfg)
    y1, st = mamba.mamba_apply(params, x[:, :16], cfg, return_state=True)
    y2, _ = mamba.mamba_apply(params, x[:, 16:], cfg, state=st)
    np.testing.assert_allclose(y_full, jnp.concatenate([y1, y2], 1),
                               rtol=2e-4, atol=2e-4)
