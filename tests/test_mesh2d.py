"""2-D ("data","model") mesh for the LM-policy paths (PR 5).

In-process (single device):
  * ``make_mesh2d`` shape/axes contract + loud over-subscription error;
  * mesh (1, 1) is BIT-identical to the unmeshed LM train AND pretrain
    steps (per-step losses and final params) — the degenerate-mesh parity
    guarantee the rl-agent path already has.

Multi-device (subprocess via conftest.run_forced, so it passes in the
single-device tier-1 env too):
  * (data=2, model=2) under 8 forced host devices matches the unmeshed
    per-step losses to 1e-5 for both LM steps (and the 3-step training
    trajectory to 1e-4 — reduction-order noise compounds through the
    optimizer), with the params genuinely model-sharded;
  * the acceptance criterion: a ``--mode lm --mesh-model 2`` run
    SIGKILLed mid-training and ``--resume``d reaches final params bitwise
    equal to an uninterrupted run (the checkpointable PackedBatchIterator
    riding inside DataSource's SourceState).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_forced, sigkill_at_boundary
from repro import checkpoint as ckpt_lib
from repro.configs import get_reduced_config
from repro.configs.base import TrainConfig
from repro.core import learner as learner_lib
from repro.data import rl_episode_batch
from repro.distributed import sharding as shd
from repro.launch.mesh import make_mesh2d
from repro.models import model as model_lib
from repro.optim import make_optimizer

B, S = 4, 16


def _lm_setup():
    cfg = get_reduced_config("qwen3-4b")
    tc = TrainConfig(optimizer="adamw", learning_rate=1e-3, grad_clip=1.0,
                     lr_schedule="constant")
    params, axes = model_lib.init(jax.random.PRNGKey(0), cfg)
    return cfg, tc, params, axes, make_optimizer(tc)


def _mesh_ctx(mesh, params0, axes):
    """(placed params, grad_constraint, rules) — exactly what train.py's
    ``_lm_mesh_setup`` builds for the LM paths."""
    rules = shd.MEGATRON_RULES
    pshard = shd.param_shardings(axes, mesh, rules, params0)
    params = jax.device_put(params0, pshard)
    grad_constraint = lambda g: jax.tree.map(  # noqa: E731
        jax.lax.with_sharding_constraint, g, pshard)
    return params, grad_constraint, rules


def _assert_trees_equal(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)


# ---------------------------------------------------------------------------
# mesh factory contract


def test_make_mesh2d_contract():
    mesh = make_mesh2d(1, 1)
    assert mesh.axis_names == ("data", "model")
    assert dict(mesh.shape) == {"data": 1, "model": 1}
    with pytest.raises(ValueError, match="devices"):
        make_mesh2d(64, 64)


# ---------------------------------------------------------------------------
# mesh (1,1) bit-parity with the unmeshed LM steps


def test_lm_train_step_mesh11_bit_identical():
    """3 IMPALA-LM learner steps through the meshed path at (1,1) == the
    unmeshed path, bit for bit (losses and final params)."""
    cfg, tc, params0, axes, opt = _lm_setup()
    rng = np.random.default_rng(0)
    batches = [{k: jnp.asarray(v) for k, v in
                rl_episode_batch(rng, B, S, cfg.vocab_size).items()}
               for _ in range(3)]

    def run(mesh):
        params, grad_constraint, rules = (params0, None, None) \
            if mesh is None else _mesh_ctx(mesh, params0, axes)
        step = jax.jit(learner_lib.make_lm_train_step(
            cfg, opt, tc, loss_chunk=S, grad_constraint=grad_constraint,
            mesh=mesh, rules=rules))
        opt_state = opt.init(params)
        losses = []
        for s, batch in enumerate(batches):
            params, opt_state, m = step(params, opt_state, jnp.int32(s),
                                        batch)
            losses.append(float(m["loss"]))
        return losses, params

    losses_a, params_a = run(None)
    losses_b, params_b = run(make_mesh2d(1, 1))
    assert losses_a == losses_b
    _assert_trees_equal(params_a, params_b)


def test_lm_pretrain_step_mesh11_bit_identical():
    """Same guarantee for the next-token pretraining step (--mode lm)."""
    cfg, tc, params0, axes, opt = _lm_setup()
    rng = np.random.default_rng(1)
    batches = [{"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32)}
        for _ in range(3)]

    def run(mesh):
        params, grad_constraint, rules = (params0, None, None) \
            if mesh is None else _mesh_ctx(mesh, params0, axes)
        step = jax.jit(learner_lib.make_lm_pretrain_step(
            cfg, opt, loss_chunk=S, grad_constraint=grad_constraint,
            mesh=mesh, rules=rules))
        opt_state = opt.init(params)
        losses = []
        for s, batch in enumerate(batches):
            params, opt_state, m = step(params, opt_state, jnp.int32(s),
                                        batch)
            losses.append(float(m["loss"]))
        return losses, params

    losses_a, params_a = run(None)
    losses_b, params_b = run(make_mesh2d(1, 1))
    assert losses_a == losses_b
    _assert_trees_equal(params_a, params_b)


# ---------------------------------------------------------------------------
# (data=2, model=2) loss parity vs unmeshed (8 forced host devices,
# hermetic subprocess — the pattern of test_sharded.py)

_PARITY_SCRIPT = r"""
import jax, jax.numpy as jnp
import numpy as np

jax.config.update("jax_default_matmul_precision", "highest")

from repro.configs import get_reduced_config
from repro.configs.base import TrainConfig
from repro.core import learner as L
from repro.data import rl_episode_batch
from repro.distributed import sharding as shd
from repro.launch.mesh import make_mesh2d
from repro.models import model as M
from repro.optim import make_optimizer

B, S = 8, 16
cfg = get_reduced_config("qwen3-4b")
tc = TrainConfig(optimizer="adamw", learning_rate=1e-3, grad_clip=1.0,
                 lr_schedule="constant")
params0, axes = M.init(jax.random.PRNGKey(0), cfg)
opt = make_optimizer(tc)
rng = np.random.default_rng(0)
rl_batches = [rl_episode_batch(rng, B, S, cfg.vocab_size)
              for _ in range(3)]
tok_batches = [{"tokens": rng.integers(0, cfg.vocab_size,
                                       (B, S + 1)).astype(np.int32)}
               for _ in range(3)]


def ctx(mesh):
    if mesh is None:
        return params0, None, None
    rules = shd.MEGATRON_RULES
    pshard = shd.param_shardings(axes, mesh, rules, params0)
    grad_constraint = lambda g: jax.tree.map(
        jax.lax.with_sharding_constraint, g, pshard)
    return jax.device_put(params0, pshard), grad_constraint, rules


def losses_on(mesh, make_step, batches, carry):
    # carry=False: every step starts from params0 (program-level parity,
    # no drift accumulation); carry=True: the real 3-step trajectory.
    params, grad_constraint, rules = ctx(mesh)
    step = jax.jit(make_step(grad_constraint, mesh, rules))
    opt_state0 = opt.init(params)
    opt_state, out = opt_state0, []
    for s, b in enumerate(batches):
        b = {k: jnp.asarray(v) for k, v in b.items()}
        if carry:
            params, opt_state, m = step(params, opt_state, jnp.int32(s), b)
        else:
            _, _, m = step(params, opt_state0, jnp.int32(0), b)
        out.append(float(m["loss"]))
    return out, params


mesh = make_mesh2d(2, 2)
for name, make_step, batches in [
    ("lm-rl", lambda gc, me, ru: L.make_lm_train_step(
        cfg, opt, tc, loss_chunk=S, grad_constraint=gc, mesh=me, rules=ru),
     rl_batches),
    ("lm", lambda gc, me, ru: L.make_lm_pretrain_step(
        cfg, opt, loss_chunk=S, grad_constraint=gc, mesh=me, rules=ru),
     tok_batches),
]:
    # per-step program parity from identical params: 1e-5
    s_ref, _ = losses_on(None, make_step, batches, carry=False)
    s_22, _ = losses_on(mesh, make_step, batches, carry=False)
    print(name, "per-step unmeshed", s_ref)
    print(name, "per-step mesh22  ", s_22)
    np.testing.assert_allclose(s_ref, s_22, rtol=1e-5, atol=1e-5)
    # 3-step trajectory: reduction-order noise compounds through the
    # adamw updates, so the bound is drift-scaled
    l_ref, _ = losses_on(None, make_step, batches, carry=True)
    l_22, p_22 = losses_on(mesh, make_step, batches, carry=True)
    print(name, "trajectory unmeshed", l_ref)
    print(name, "trajectory mesh22  ", l_22)
    np.testing.assert_allclose(l_ref, l_22, rtol=1e-4, atol=1e-4)
    # the params are genuinely distributed: at least one leaf spans >1
    # device with strictly smaller per-device shards (model-sharded)
    sharded = [x for x in jax.tree.leaves(p_22)
               if len(x.sharding.device_set) == 4
               and any(s.data.shape != x.shape
                       for s in x.addressable_shards)]
    assert sharded, name + ": no parameter actually model-sharded"
print("MESH2D PARITY OK")
"""


def test_lm_mesh22_matches_unmeshed_subprocess():
    proc = run_forced(script=_PARITY_SCRIPT, devices=8)
    assert "MESH2D PARITY OK" in proc.stdout


# ---------------------------------------------------------------------------
# acceptance: --mode lm --mesh-model 2, SIGKILLed, --resume, bitwise
# (subprocess under 2 forced host devices so it runs everywhere)


def _lm_cmd(ckpt_dir, extra=()):
    return ["-m", "repro.launch.train", "--mode", "lm", "--arch",
            "qwen3-4b", "--reduced", "--batch", "8", "--seq", "32",
            "--steps", "8", "--mesh-model", "2",
            "--checkpoint-dir", ckpt_dir, *extra]


def test_lm_mesh_model_sigkill_resume_bit_exact(tmp_path):
    dir_a, dir_b = str(tmp_path / "a"), str(tmp_path / "b")

    # leg A: uninterrupted
    run_forced(_lm_cmd(dir_a), devices=2)

    # leg B: SIGKILL once the step-3 boundary checkpoint lands
    sigkill_at_boundary(_lm_cmd(dir_b, ["--checkpoint-every", "3"]),
                        dir_b, 3, devices=2)

    # leg C: resume to the same horizon
    proc = run_forced(_lm_cmd(dir_b, ["--resume"]), devices=2)
    assert "source state restored" in proc.stdout

    # the iterator position rode inside the DataSource state
    state = ckpt_lib.restore_structured(os.path.join(dir_b, "step_3"),
                                        "source")
    assert state["kind"] == "DataSource"
    assert state["iterator"]["kind"] == "PackedBatchIterator"
    assert state["iterator"]["offset"] == 3

    # final params + optimizer state bitwise identical to leg A
    flat_a, _ = ckpt_lib.load_flat(os.path.join(dir_a, "step_8"))
    flat_b, _ = ckpt_lib.load_flat(os.path.join(dir_b, "step_8"))
    checked = 0
    for k in flat_a:
        if k.startswith(("params/", "opt_state/")):
            np.testing.assert_array_equal(flat_a[k], flat_b[k], err_msg=k)
            checked += 1
    assert checked > 0
