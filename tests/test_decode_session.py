"""DecodeSession / continuous-batching server contract tests.

The load-bearing guarantees of the serving redesign:
  * a single-request continuous server is BITWISE-identical to
    ``core.generate.generate`` with the same seed,
  * admission/eviction of neighbours never perturbs a surviving slot,
  * slot recycling never leaks KV state across tenants,
  * per-request max_tokens / temperature / stop_token are honoured,
  * ImplContext folds the CLI impl flags into the config exactly once.
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.configs.base import ImplContext
from repro.core import generate as G
from repro.launch.serve import Server
from repro.models import model as model_lib

P, N = 4, 8   # prompt length (on the bucket ladder), generation budget


@pytest.fixture(scope="module", params=["qwen3-4b", "xlstm-125m"])
def setup(request):
    cfg = get_reduced_config(request.param)
    params, _ = model_lib.init(jax.random.PRNGKey(0), cfg)
    prompt = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (1, P), 0, cfg.vocab_size))
    key = jax.random.PRNGKey(7)
    ref = jax.tree.map(np.asarray,
                       G.generate(params, jnp.asarray(prompt), key,
                                  cfg=cfg, num_steps=N))
    return cfg, params, prompt, key, ref


def _run_session(sess, slot, prompt, key, n):
    out0 = sess.prefill_into(slot, prompt, key=key)
    toks, lps = [out0["token"]], [out0["logprob"]]
    for _ in range(n - 1):
        o = sess.step()
        toks.append(o["token"][slot])
        lps.append(o["logprob"][slot])
    return np.asarray(toks), np.asarray(lps)


def test_single_request_bitwise_parity_with_generate(setup):
    """Server (max_batch=1) vs generate(): identical tokens AND logprobs,
    bitwise — both run the same compiled session functions."""
    cfg, params, prompt, key, ref = setup
    k0 = np.asarray(jax.random.split(key, 1)[0])
    server = Server(cfg, params, max_batch=1, max_len=P + N).start()
    try:
        h = server.submit(prompt[0], max_tokens=N, key=k0)
        tokens = h.result(timeout=300)
    finally:
        server.stop()
    np.testing.assert_array_equal(tokens, ref["tokens"][0])


def test_admission_eviction_preserves_survivors(setup):
    """A slot's stream is a pure function of its own (prompt, key): bitwise
    equal to the same slot decoding ALONE in an identically-shaped session,
    while neighbours are admitted, evicted and re-admitted around it."""
    cfg, params, prompt, key, ref = setup
    k0 = np.asarray(jax.random.split(key, 1)[0])

    solo = G.DecodeSession(params, cfg, max_batch=4, max_len=P + N)
    want_t, want_lp = _run_session(solo, 2, prompt[0], k0, N)

    sess = G.DecodeSession(params, cfg, max_batch=4, max_len=P + N)
    rng = np.random.default_rng(0)
    out0 = sess.prefill_into(2, prompt[0], key=k0)
    toks, lps = [out0["token"]], [out0["logprob"]]
    sess.prefill_into(0, rng.integers(0, cfg.vocab_size, size=3),
                      key=np.asarray(jax.random.PRNGKey(11)),
                      temperature=0.7)
    for i in range(N - 1):
        if i == 2:
            sess.evict(0)
        if i == 4:   # recycle the freed slot mid-flight
            sess.prefill_into(0, rng.integers(0, cfg.vocab_size, size=2),
                              key=np.asarray(jax.random.PRNGKey(13)))
        o = sess.step()
        toks.append(o["token"][2])
        lps.append(o["logprob"][2])
    np.testing.assert_array_equal(np.asarray(toks), want_t)
    np.testing.assert_array_equal(np.asarray(lps), want_lp)
    # token stream also matches the B=1 generate() reference
    np.testing.assert_array_equal(np.asarray(toks), ref["tokens"][0, P:])


def test_slot_recycling_never_leaks_kv(setup):
    """Tenant B in a recycled slot decodes exactly as in a fresh session —
    nothing of tenant A's KV/RNG/position state survives admission."""
    cfg, params, prompt, key, ref = setup
    kb = np.asarray(jax.random.PRNGKey(21))
    prompt_b = np.asarray(jax.random.randint(
        jax.random.PRNGKey(22), (P,), 0, cfg.vocab_size))

    fresh = G.DecodeSession(params, cfg, max_batch=1, max_len=P + N)
    want_t, want_lp = _run_session(fresh, 0, prompt_b, kb, N)

    recycled = G.DecodeSession(params, cfg, max_batch=1, max_len=P + N)
    _run_session(recycled, 0, prompt[0],
                 np.asarray(jax.random.split(key, 1)[0]), N)
    recycled.evict(0)
    got_t, got_lp = _run_session(recycled, 0, prompt_b, kb, N)
    np.testing.assert_array_equal(got_t, want_t)
    np.testing.assert_array_equal(got_lp, want_lp)


def test_per_request_budget_and_stop_token(setup):
    """max_tokens truncates to a prefix of the full stream; stop_token ends
    the request the moment it is sampled (stop included in the result)."""
    cfg, params, prompt, key, ref = setup
    k0 = np.asarray(jax.random.split(key, 1)[0])
    full = ref["tokens"][0, P:]
    stop = int(full[2])
    server = Server(cfg, params, max_batch=2, max_len=P + N).start()
    try:
        h_budget = server.submit(prompt[0], max_tokens=3, key=k0)
        h_stop = server.submit(prompt[0], max_tokens=N, stop_token=stop,
                               key=k0)
        np.testing.assert_array_equal(h_budget.result(timeout=300)[P:],
                                      full[:3])
        np.testing.assert_array_equal(h_stop.result(timeout=300)[P:],
                                      full[:3])
    finally:
        server.stop()


def test_static_and_continuous_agree_per_request():
    """Streams are request-local, so the scheduling policy must not change
    any request's tokens — only the step count (continuous admits into
    freed slots instead of waiting for the whole batch)."""
    cfg = get_reduced_config("qwen3-4b")
    params, _ = model_lib.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(rng.integers(1, 6)))
               for _ in range(5)]
    keys = [np.asarray(jax.random.PRNGKey(100 + i)) for i in range(5)]
    budgets = [1 + i for i in range(5)]

    def run(policy):
        server = Server(cfg, params, max_batch=2, max_len=16,
                        policy=policy).start()
        try:
            hs = [server.submit(p, max_tokens=n, key=k)
                  for p, n, k in zip(prompts, budgets, keys)]
            return [h.result(timeout=300) for h in hs], server.steps
        finally:
            server.stop()

    cont, cont_steps = run("continuous")
    stat, stat_steps = run("static")
    for a, b in zip(cont, stat):
        np.testing.assert_array_equal(a, b)
    assert cont_steps <= stat_steps


def test_temperature_changes_stream_deterministically():
    cfg = get_reduced_config("qwen3-4b")
    params, _ = model_lib.init(jax.random.PRNGKey(0), cfg)
    prompt = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (P,), 0, cfg.vocab_size))
    k = np.asarray(jax.random.PRNGKey(5))

    def run(temp):
        sess = G.DecodeSession(params, cfg, max_batch=1, max_len=P + N)
        out0 = sess.prefill_into(0, prompt, key=k, temperature=temp)
        toks = [out0["token"]]
        for _ in range(N - 1):
            toks.append(sess.step()["token"][0])
        return np.asarray(toks)

    np.testing.assert_array_equal(run(0.5), run(0.5))
    # greedy-ish vs hot sampling must diverge for an untrained model
    assert not np.array_equal(run(0.05), run(5.0))


# ---------------------------------------------------------------------------
# ImplContext + prefill bucketing
# ---------------------------------------------------------------------------

def test_impl_context_resolves_once_at_the_boundary():
    cfg = get_reduced_config("qwen3-4b")
    ns = argparse.Namespace(attn_impl="kernel", ssd_impl=None)
    out = ImplContext.from_args(ns).apply(cfg)
    assert out.attn_impl == "kernel"
    assert out.ssd_impl == cfg.ssd_impl          # None field: keep config
    assert ImplContext().apply(cfg) is cfg       # no-op returns same cfg
    both = ImplContext(attn="xla", ssd="kernel").apply(cfg)
    assert (both.attn_impl, both.ssd_impl) == ("xla", "kernel")


def test_prefill_len_bucketing_rules():
    full = get_reduced_config("qwen3-4b")         # full causal attention
    assert G.prefill_len(full, 5, 64) == 8        # ladder pad
    assert G.prefill_len(full, 100, 64) == 64     # clamp to capacity
    rec = get_reduced_config("xlstm-125m")        # recurrent: exact
    assert rec.is_recurrent
    assert G.prefill_len(rec, 5, 64) == 5
    win = dataclasses.replace(
        full, block_pattern=(("swa_attn", "swiglu"),), sliding_window=4)
    assert G.prefill_len(win, 3, 64) == 4         # bucket within the window
    assert G.prefill_len(win, 5, 64) == 5         # bucket 8 > window: exact
