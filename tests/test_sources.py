"""RolloutSource contract tests: every source emits the canonical
time-major rollout layout (core/sources.py), the on-device and host-loop
actor paths are shape/dtype-identical for the same env config, and the
double-buffered device path is bit-identical to the synchronous path when
the parameters do not move (parameter lag 0)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.atari_impala import small_train
from repro.core import learner as learner_lib
from repro.core.runtime import Runtime
from repro.core.sources import (DataSource, DeviceSource, GeneratorSource,
                                HostLoopSource, check_rollout,
                                lm_rl_step_from_rollout)
from repro.envs import catch
from repro.models.convnet import init_agent, minatar_net
from repro.optim import make_optimizer

T, B = 5, 4


def _agent():
    env = catch.make()
    init_fn, apply_fn = minatar_net(env.obs_shape, env.num_actions)
    params, _ = init_agent(init_fn, jax.random.PRNGKey(0))
    return env, apply_fn, params


def _shapes_dtypes(rollout):
    return jax.tree.map(
        lambda x: (tuple(x.shape), jnp.asarray(x).dtype), rollout)


def test_device_and_host_sources_identical_contract():
    """Same env config -> identical rollout pytree shapes/dtypes from the
    compiled and the MonoBeast actor architectures."""
    env, apply_fn, params = _agent()
    dev = DeviceSource.for_env(env, apply_fn, unroll_length=T, batch_size=B,
                               key=jax.random.PRNGKey(1), pipelined=False)
    host = HostLoopSource(env, apply_fn, num_actors=B, unroll_length=T,
                          batch_size=B)
    try:
        host.start(params)
        r_dev = dev.next_batch(params)
        r_host = host.next_batch(params)
    finally:
        host.stop()
        dev.stop()
    check_rollout(r_dev, T, B)
    check_rollout(r_host, T, B)
    assert _shapes_dtypes(r_dev) == _shapes_dtypes(r_host)
    assert dev.frames_per_batch == host.frames_per_batch == T * B


def test_generator_source_contract():
    """The LM token-MDP source obeys the same time-major contract (with
    chosen-action behavior log-probs), and its rollouts feed the adapted
    LM learner step."""
    from repro.configs import get_reduced_config
    from repro.configs.base import TrainConfig
    from repro.models import model as M
    cfg = get_reduced_config("xlstm-125m")
    params, _ = M.init(jax.random.PRNGKey(0), cfg)
    src = GeneratorSource(cfg, batch_size=B, episode_length=T,
                          key=jax.random.PRNGKey(2))
    r = src.next_batch(params)
    check_rollout(r, T, B)
    assert r["obs"].shape == (T + 1, B)  # token ids are the observations
    np.testing.assert_array_equal(np.asarray(r["action"]),
                                  np.asarray(r["obs"][1:]))
    assert src.frames_per_batch == T * B

    tc = TrainConfig(optimizer="adamw", learning_rate=1e-3, grad_clip=1.0,
                     lr_schedule="constant")
    opt = make_optimizer(tc)
    step = jax.jit(lm_rl_step_from_rollout(
        learner_lib.make_lm_train_step(cfg, opt, tc, loss_chunk=T)))
    _, _, m = step(params, opt.init(params), jnp.int32(0), r)
    assert bool(jnp.isfinite(m["loss"]))


def test_pipelined_matches_sync_bit_for_bit():
    """At parameter lag 0 (frozen params) double buffering must be purely
    mechanical: the rollout stream is bit-identical to synchronous."""
    env, apply_fn, params = _agent()

    def make(pipelined):
        return DeviceSource.for_env(
            env, apply_fn, unroll_length=T, batch_size=B,
            key=jax.random.PRNGKey(3), pipelined=pipelined)

    sync, pipe = make(False), make(True)
    for _ in range(4):
        a = sync.next_batch(params)
        b = pipe.next_batch(params)
        jax.tree.map(lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)), a, b)


def test_param_sync_every_lags_behavior_params():
    """The actor-lag knob: behavior params refresh only every k-th unroll
    (the vtrace_ablation lag mechanism)."""
    env, apply_fn, params = _agent()
    src = DeviceSource.for_env(env, apply_fn, unroll_length=T, batch_size=B,
                               key=jax.random.PRNGKey(4), pipelined=False,
                               param_sync_every=2)
    newer = jax.tree.map(lambda x: x + 1.0, params)
    src.next_batch(params)                       # dispatch 0: sync
    src.next_batch(newer)                        # dispatch 1: hold
    assert src._behavior_params is params
    src.next_batch(newer)                        # dispatch 2: sync
    assert src._behavior_params is newer


def test_runtime_trains_logs_and_checkpoints(tmp_path):
    """The unified loop: metrics come back finite, FPS/frames accounting
    accumulates, and the final checkpoint lands on disk."""
    env, apply_fn, params = _agent()
    tc = small_train(unroll_length=T, batch_size=B, total_steps=100)
    opt = make_optimizer(tc)
    src = DeviceSource.for_env(env, apply_fn, unroll_length=T, batch_size=B,
                               key=jax.random.PRNGKey(5), pipelined=True)
    step = jax.jit(learner_lib.make_train_step(apply_fn, opt, tc))
    lines = []
    rt = Runtime(src, step, params, opt.init(params), total_steps=4,
                 log_every=2, checkpoint_dir=str(tmp_path),
                 print_fn=lines.append)
    rt.run()
    assert (tmp_path / "step_4" / "manifest.json").exists()
    assert any("reward/step=" in ln for ln in lines)
    assert rt.frames == 4 * T * B
    assert bool(jnp.isfinite(rt.metrics["loss"]))


def test_host_loop_stop_leaves_no_live_threads():
    """Regression: stop() must close AND join the inference thread along
    with the actor pool — a leaked inference thread keeps evaluating the
    policy with the stale params of the stopped run."""
    import threading
    env, apply_fn, params = _agent()
    before = set(threading.enumerate())
    host = HostLoopSource(env, apply_fn, num_actors=2, unroll_length=T,
                          batch_size=2)
    host.start(params)
    host.next_batch(params)
    spawned = [t for t in threading.enumerate() if t not in before]
    assert any(t.name == "inference" for t in spawned)
    host.stop()
    leaked = [t for t in threading.enumerate()
              if t not in before and t.is_alive()]
    assert leaked == [], f"stop() leaked threads: {leaked}"
    assert host._params is None          # no stale params held after stop


def test_data_source_wraps_iterator():
    batches = iter([{"tokens": np.zeros((2, 3), np.int32)}] * 3)
    closed = []
    src = DataSource(batches, frames_per_batch=6,
                     transform=lambda b: {k: jnp.asarray(v)
                                          for k, v in b.items()},
                     close=lambda: closed.append(True))
    src.start(None)
    out = src.next_batch(None)
    assert out["tokens"].shape == (2, 3)
    src.stop()
    assert closed == [True]
