"""SourceState checkpoint/restore protocol (PR 4): sources are stateful,
checkpointable objects, and a killed-and-resumed run is BIT-IDENTICAL to
an uninterrupted one.

* the structured (self-describing) checkpoint layer round-trips nested
  dicts/tuples/lists/None/scalars/arrays (incl. the numpy Generator state
  with its 128-bit integers);
* ``state_dict``/``load_state_dict`` round-trip every source: restored
  replicas emit the exact same rollout stream;
* resume composition mismatches (saved --replay, resumed without; wrong
  buffer kind) fail loudly instead of silently restarting fresh;
* the full guarantee, in process: a Runtime crash mid-training, resumed
  from the crash checkpoint, reaches final params bitwise equal to an
  uninterrupted run;
* the acceptance criterion, via the CLI: a ``--mesh-data 2 --replay
  elite`` run SIGKILLed mid-training and ``--resume``d matches the
  uninterrupted run's final params bitwise (subprocess, 2 forced host
  devices).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_forced, sigkill_at_boundary
from repro import checkpoint as ckpt_lib
from repro.configs.atari_impala import small_train
from repro.core import learner as learner_lib
from repro.core.replay import make_buffer
from repro.core.runtime import Runtime
from repro.core.sources import (DataSource, DeviceSource, GeneratorSource,
                                ReplaySource, ShardedDeviceSource)
from repro.envs import catch
from repro.launch.mesh import make_data_mesh
from repro.models.convnet import init_agent, minatar_net
from repro.optim import make_optimizer

T, B = 5, 4


def _agent():
    env = catch.make()
    init_fn, apply_fn = minatar_net(env.obs_shape, env.num_actions)
    params, _ = init_agent(init_fn, jax.random.PRNGKey(0))
    return env, apply_fn, params


def _assert_trees_equal(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)


# ---------------------------------------------------------------------------
# structured checkpoint layer


def test_structured_checkpoint_roundtrip(tmp_path):
    rng = np.random.default_rng(3)
    state = {
        "kind": "Thing",
        "none": None,
        "nested": {"tuple": (np.arange(6).reshape(2, 3), "s", 4.5),
                   "list": [True, np.float32(1.5), None]},
        "rng": rng.bit_generator.state,          # 128-bit ints survive JSON
        "arr": np.ones((3, 2), np.float32),
    }
    path = str(tmp_path / "c.npz")
    ckpt_lib.save(path, {"x": jnp.zeros(2)}, {"step": 7},
                  structured={"source": state})
    out = ckpt_lib.restore_structured(path, "source")
    assert out["kind"] == "Thing" and out["none"] is None
    tup = out["nested"]["tuple"]
    assert isinstance(tup, tuple) and tup[1] == "s" and tup[2] == 4.5
    np.testing.assert_array_equal(tup[0], np.arange(6).reshape(2, 3))
    assert out["nested"]["list"] == [True, 1.5, None]
    assert out["rng"] == rng.bit_generator.state
    np.testing.assert_array_equal(out["arr"], state["arr"])
    # the fixed-structure layer still restores alongside
    restored, meta = ckpt_lib.restore(path, {"x": jnp.ones(2)})
    assert meta["step"] == 7
    np.testing.assert_array_equal(restored["x"], np.zeros(2))


def test_restore_structured_absent_returns_none(tmp_path):
    """Pre-protocol checkpoints (and missing names) restore as None — the
    caller starts that piece fresh instead of crashing."""
    path = str(tmp_path / "old.npz")
    ckpt_lib.save(path, {"x": jnp.zeros(2)}, {"step": 1})
    assert ckpt_lib.restore_structured(path, "source") is None
    ckpt_lib.save(path, {"x": jnp.zeros(2)}, {"step": 1},
                  structured={"other": {"kind": "X"}})
    assert ckpt_lib.restore_structured(path, "source") is None


# ---------------------------------------------------------------------------
# per-source state round-trips: a restored replica continues the stream


@pytest.mark.parametrize("pipelined", [False, True])
def test_device_source_state_roundtrip(tmp_path, pipelined):
    env, apply_fn, params = _agent()

    def make(key):
        return DeviceSource.for_env(env, apply_fn, unroll_length=T,
                                    batch_size=B,
                                    key=jax.random.PRNGKey(key),
                                    pipelined=pipelined,
                                    param_sync_every=2)

    a = make(3)
    for _ in range(3):
        a.next_batch(params)
    path = str(tmp_path / "s.npz")
    ckpt_lib.save(path, {"x": jnp.zeros(1)}, {},
                  structured={"source": a.state_dict()})
    b = make(99)                      # different key: state must win
    b.load_state_dict(ckpt_lib.restore_structured(path, "source"))
    assert b._dispatches == a._dispatches
    for _ in range(3):
        _assert_trees_equal(a.next_batch(params), b.next_batch(params))


def test_sharded_source_state_roundtrip_mesh1(tmp_path):
    env, apply_fn, params = _agent()
    mesh = make_data_mesh(1)

    def make(key):
        return ShardedDeviceSource.for_env(
            env, apply_fn, unroll_length=T, batch_size=B,
            key=jax.random.PRNGKey(key), mesh=mesh, pipelined=True)

    a = make(3)
    for _ in range(2):
        a.next_batch(params)
    path = str(tmp_path / "s.npz")
    ckpt_lib.save(path, {"x": jnp.zeros(1)}, {},
                  structured={"source": a.state_dict()})
    b = make(42)
    b.load_state_dict(ckpt_lib.restore_structured(path, "source"))
    for _ in range(3):
        _assert_trees_equal(a.next_batch(params), b.next_batch(params))


def test_replay_source_state_roundtrip_with_priorities(tmp_path):
    """The nested checkpoint: inner stream + buffer slots/priorities + RNG
    all survive, so the restored replica samples the exact same replayed
    columns and routes priorities to the same slots."""
    env, apply_fn, params = _agent()

    def make(key, seed):
        src = DeviceSource.for_env(env, apply_fn, unroll_length=T,
                                   batch_size=B,
                                   key=jax.random.PRNGKey(key),
                                   pipelined=True)
        return ReplaySource(src, make_buffer("elite", 16),
                            replay_ratio=1.0, seed=seed,
                            value_fn=jax.jit(
                                lambda p, o: apply_fn(p, o).baseline))

    a = make(5, 7)
    a.start(params)
    for i in range(4):
        batch = a.next_batch(params)
        prio = np.abs(np.asarray(batch["reward"]).mean(0)) + 0.1
        a.on_learner_metrics(i, {"priority": prio})
    path = str(tmp_path / "s.npz")
    ckpt_lib.save(path, {"x": jnp.zeros(1)}, {},
                  structured={"source": a.state_dict()})

    b = make(6, 0)                    # different key AND replay seed
    b.load_state_dict(ckpt_lib.restore_structured(path, "source"))
    # buffer occupancy and priorities survived the restart
    assert len(b.buffer) == len(a.buffer)
    np.testing.assert_array_equal(b.buffer._prio, a.buffer._prio)
    np.testing.assert_array_equal(b.buffer._live, a.buffer._live)
    for i in range(3):
        ra, rb = a.next_batch(params), b.next_batch(params)
        _assert_trees_equal(ra, rb)
        assert a._last_ids == b._last_ids
        prio = np.abs(np.asarray(ra["reward"]).mean(0)) + 0.1
        a.on_learner_metrics(i, {"priority": prio})
        b.on_learner_metrics(i, {"priority": prio})
        np.testing.assert_array_equal(a.buffer._prio, b.buffer._prio)


def test_generator_and_data_source_state():
    from repro.configs import get_reduced_config
    cfg = get_reduced_config("xlstm-125m")
    from repro.models import model as M
    params, _ = M.init(jax.random.PRNGKey(0), cfg)
    a = GeneratorSource(cfg, batch_size=2, episode_length=3,
                        key=jax.random.PRNGKey(2))
    a.next_batch(params)
    b = GeneratorSource(cfg, batch_size=2, episode_length=3,
                        key=jax.random.PRNGKey(9))
    b.load_state_dict(a.state_dict())
    _assert_trees_equal(a.next_batch(params), b.next_batch(params))

    d = DataSource(iter([]), frames_per_batch=1)
    d.load_state_dict(d.state_dict())  # stateless but protocol-complete


def test_packed_batch_iterator_state_roundtrip():
    """seed+offset checkpointing: batch i depends on (seed, i) alone, so a
    restored replica replays the exact stream — including batches the
    killed run had prefetched but never consumed."""
    from repro.data import PackedBatchIterator, markov_corpus
    corpus = markov_corpus(64, 3000, seed=5)
    a = PackedBatchIterator(corpus, 4, 16, seed=11)
    b = PackedBatchIterator(corpus, 4, 16, seed=999)  # state must win
    try:
        for _ in range(3):
            next(a)
        state = a.state_dict()
        assert state == {"kind": "PackedBatchIterator", "seed": 11,
                         "offset": 3}
        b.load_state_dict(state)
        for _ in range(4):
            np.testing.assert_array_equal(next(a)["tokens"],
                                          next(b)["tokens"])
    finally:
        a.close()
        b.close()


def test_data_source_checkpoints_iterator_position():
    """DataSource nests a checkpointable iterator's state — the --mode lm
    piece of the bit-exact --resume guarantee."""
    from repro.data import PackedBatchIterator, markov_corpus
    corpus = markov_corpus(64, 3000, seed=5)
    ia = PackedBatchIterator(corpus, 4, 16, seed=1)
    ib = PackedBatchIterator(corpus, 4, 16, seed=2)
    a = DataSource(ia, frames_per_batch=64, close=ia.close)
    b = DataSource(ib, frames_per_batch=64, close=ib.close)
    try:
        a.next_batch(None)
        a.next_batch(None)
        state = a.state_dict()
        assert state["iterator"]["offset"] == 2
        b.load_state_dict(state)
        for _ in range(3):
            np.testing.assert_array_equal(a.next_batch(None)["tokens"],
                                          b.next_batch(None)["tokens"])
        # saved iterator state, resumed with a non-checkpointable iterator:
        # loud failure, not a silent fresh start
        with pytest.raises(ValueError, match="not checkpointable"):
            DataSource(iter([]), frames_per_batch=1).load_state_dict(state)
        # mismatched iterator kinds fail loudly too
        with pytest.raises(ValueError, match="same data pipeline"):
            ib.load_state_dict({"kind": "SomethingElse"})
    finally:
        a.stop()
        b.stop()


def test_resume_composition_mismatch_fails_loudly():
    env, apply_fn, params = _agent()
    dev = DeviceSource.for_env(env, apply_fn, unroll_length=T, batch_size=B,
                               key=jax.random.PRNGKey(1))
    rs = ReplaySource(
        DeviceSource.for_env(env, apply_fn, unroll_length=T, batch_size=B,
                             key=jax.random.PRNGKey(1)),
        make_buffer("uniform", 8))
    # saved bare DeviceSource, resumed with --replay (and vice versa)
    with pytest.raises(ValueError, match="same source flags"):
        rs.load_state_dict(dev.state_dict())
    with pytest.raises(ValueError, match="same source flags"):
        dev.load_state_dict(rs.state_dict())
    # saved elite, resumed uniform
    uni = make_buffer("uniform", 8)
    with pytest.raises(ValueError, match="--replay"):
        uni.load_state_dict(make_buffer("elite", 8).state_dict())
    # same kind, different capacity
    with pytest.raises(ValueError, match="--replay-capacity"):
        uni.load_state_dict(make_buffer("uniform", 16).state_dict())


# ---------------------------------------------------------------------------
# the full guarantee, in process: crash -> resume == uninterrupted


def test_crash_resume_bit_identical_to_uninterrupted(tmp_path):
    """A run that dies mid-training (crash checkpoint) and resumes reaches
    final params BITWISE equal to a run that never died — env carries,
    RNG streams, the in-flight pipelined rollout, replay contents and
    priorities all resume exactly."""
    env, apply_fn, params0 = _agent()
    tc = small_train(unroll_length=T, batch_size=B, total_steps=8,
                     clear_policy_cost=0.01, clear_value_cost=0.005)
    opt = make_optimizer(tc)

    def make_source():
        src = DeviceSource.for_env(env, apply_fn, unroll_length=T,
                                   batch_size=B,
                                   key=jax.random.PRNGKey(11),
                                   pipelined=True)
        return ReplaySource(src, make_buffer("elite", 16),
                            replay_ratio=1.0, seed=3,
                            value_fn=jax.jit(
                                lambda p, o: apply_fn(p, o).baseline))

    step = jax.jit(learner_lib.make_train_step(apply_fn, opt, tc))

    # uninterrupted reference
    rt = Runtime(make_source(), step, params0, opt.init(params0),
                 total_steps=8, log_every=0, print_fn=lambda s: None)
    params_a, _ = rt.run()

    # crash at step 5 (after the update + priority feedback), resume
    def boom(s, m):
        if s == 5:
            raise RuntimeError("killed")

    rt1 = Runtime(make_source(), step, params0, opt.init(params0),
                  total_steps=8, log_every=0, on_metrics=boom,
                  checkpoint_dir=str(tmp_path), print_fn=lambda s: None)
    with pytest.raises(RuntimeError):
        rt1.run()
    path = ckpt_lib.latest_step_path(str(tmp_path))
    assert os.path.basename(path) == "step_6"
    restored, meta = ckpt_lib.restore(
        path, {"params": params0, "opt_state": opt.init(params0)})
    source = make_source()
    source.load_state_dict(ckpt_lib.restore_structured(path, "source"))
    rt2 = Runtime(source, step, restored["params"], restored["opt_state"],
                  total_steps=8, start_step=meta["step"], log_every=0,
                  print_fn=lambda s: None)
    params_b, _ = rt2.run()
    _assert_trees_equal(params_a, params_b)


def test_crash_snapshot_never_clobbers_boundary_checkpoint(tmp_path):
    """A crash INSIDE a step (after the source advanced) must not
    overwrite the boundary checkpoint a periodic save already wrote for
    that step — the boundary one is the source-consistent state bit-exact
    resume depends on."""
    env, apply_fn, params0 = _agent()
    tc = small_train(unroll_length=T, batch_size=B, total_steps=8,
                     clear_policy_cost=0.01, clear_value_cost=0.005)
    opt = make_optimizer(tc)

    def make_source():
        src = DeviceSource.for_env(env, apply_fn, unroll_length=T,
                                   batch_size=B,
                                   key=jax.random.PRNGKey(21),
                                   pipelined=True)
        return ReplaySource(src, make_buffer("elite", 16),
                            replay_ratio=1.0, seed=9,
                            value_fn=jax.jit(
                                lambda p, o: apply_fn(p, o).baseline))

    step = jax.jit(learner_lib.make_train_step(apply_fn, opt, tc))
    rt = Runtime(make_source(), step, params0, opt.init(params0),
                 total_steps=8, log_every=0, print_fn=lambda s: None)
    params_ref, _ = rt.run()

    # crash DURING step 5 (after next_batch advanced the source), with a
    # periodic boundary checkpoint already written at step 5
    calls = {"n": 0}

    def crashing_step(p, o, s, batch):
        if calls["n"] == 5:
            raise TimeoutError("learner stalled mid-step")
        calls["n"] += 1
        return step(p, o, s, batch)

    lines = []
    rt1 = Runtime(make_source(), crashing_step, params0,
                  opt.init(params0), total_steps=8, log_every=0,
                  checkpoint_dir=str(tmp_path), checkpoint_every=5,
                  print_fn=lines.append)
    with pytest.raises(TimeoutError):
        rt1.run()
    assert any("crash checkpoint skipped" in ln for ln in lines)

    # resume from the (preserved) boundary checkpoint: still bit-exact
    path = ckpt_lib.latest_step_path(str(tmp_path))
    assert os.path.basename(path) == "step_5"
    restored, meta = ckpt_lib.restore(
        path, {"params": params0, "opt_state": opt.init(params0)})
    source = make_source()
    source.load_state_dict(ckpt_lib.restore_structured(path, "source"))
    rt2 = Runtime(source, step, restored["params"], restored["opt_state"],
                  total_steps=8, start_step=meta["step"], log_every=0,
                  print_fn=lambda s: None)
    params_b, _ = rt2.run()
    _assert_trees_equal(params_ref, params_b)


def test_final_checkpoint_captures_live_source_state(tmp_path):
    """The final checkpoint is written BEFORE source.stop() — it must hold
    the live stream state (stop() resets it), so run-to-N-then-resume
    continues the exact stream."""
    env, apply_fn, params = _agent()
    tc = small_train(unroll_length=T, batch_size=B, total_steps=8)
    opt = make_optimizer(tc)
    src = DeviceSource.for_env(env, apply_fn, unroll_length=T, batch_size=B,
                               key=jax.random.PRNGKey(2), pipelined=True)
    step = jax.jit(learner_lib.make_train_step(apply_fn, opt, tc))
    rt = Runtime(src, step, params, opt.init(params), total_steps=3,
                 log_every=0, checkpoint_dir=str(tmp_path),
                 print_fn=lambda s: None)
    rt.run()
    state = ckpt_lib.restore_structured(str(tmp_path / "step_3"),
                                        "source")
    assert state["kind"] == "DeviceSource"
    assert state["dispatches"] > 0          # live state, not the reset one
    assert state["pending"] is not None     # in-flight rollout captured


# ---------------------------------------------------------------------------
# acceptance: --mesh-data 2 --replay elite, SIGKILLed, --resume, bitwise
# (subprocess under 2 forced host devices so it runs everywhere)


def _train_cmd(ckpt_dir, extra=()):
    return ["-m", "repro.launch.train", "--mode", "rl-agent",
            "--env", "catch", "--batch", "8", "--steps", "10",
            "--mesh-data", "2", "--replay", "elite",
            "--replay-capacity", "32", "--checkpoint-dir", ckpt_dir,
            *extra]


def test_mesh2_elite_sigkill_resume_bit_exact(tmp_path):
    dir_a, dir_b = str(tmp_path / "a"), str(tmp_path / "b")

    # leg A: uninterrupted
    run_forced(_train_cmd(dir_a), devices=2)

    # leg B: SIGKILL once the step-3 boundary checkpoint lands
    sigkill_at_boundary(_train_cmd(dir_b, ["--checkpoint-every", "3"]),
                        dir_b, 3, devices=2)

    # leg C: resume to the same horizon
    proc = run_forced(_train_cmd(dir_b, ["--resume"]), devices=2)
    assert "source state restored" in proc.stdout

    # replay occupancy + non-default priorities survived into the resume
    state = ckpt_lib.restore_structured(os.path.join(dir_b, "step_3"),
                                        "source")
    assert state["kind"] == "ReplaySource"
    assert state["buffer"]["kind"] == "ShardedReplay"
    live = sum(int(part["live"].sum()) for part in state["buffer"]["parts"])
    assert live > 0
    prios = np.concatenate([part["prio"][part["live"]]
                            for part in state["buffer"]["parts"]])
    assert len(np.unique(prios)) > 1     # learner feedback, not defaults

    # final params bitwise identical to the uninterrupted run
    flat_a, _ = ckpt_lib.load_flat(os.path.join(dir_a, "step_10"))
    flat_b, _ = ckpt_lib.load_flat(os.path.join(dir_b, "step_10"))
    assert set(flat_a) == set(flat_b) and flat_a
    for k in flat_a:
        np.testing.assert_array_equal(flat_a[k], flat_b[k], err_msg=k)
