"""TorchBeast recurrent-agent (core_state) path and the MonoBeast shared
rollout buffers (free/full queue recycling)."""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.atari_impala import small_train
from repro.core import learner as learner_lib
from repro.core import rollout as rollout_lib
from repro.core.rollout_buffers import RolloutBuffers, rollout_specs
from repro.envs import catch
from repro.models.convnet import init_agent, minatar_lstm_net
from repro.optim import make_optimizer


def test_lstm_core_state_resets_on_done():
    init_fn, apply_fn, init_state = minatar_lstm_net((10, 5, 1), 3)
    params, _ = init_agent(init_fn, jax.random.PRNGKey(0))
    obs = jax.random.uniform(jax.random.PRNGKey(1), (2, 10, 5, 1))
    st = (jnp.ones((2, 128)), jnp.ones((2, 128)))
    done = jnp.array([True, False])
    out = apply_fn(params, obs, st, done)
    out_fresh = apply_fn(params, obs, init_state(2), None)
    # row 0 (done) behaves as if the state were zeroed
    np.testing.assert_allclose(out.policy_logits[0],
                               out_fresh.policy_logits[0], rtol=1e-5)
    # row 1 keeps its state (different from fresh)
    assert float(jnp.abs(out.policy_logits[1]
                         - out_fresh.policy_logits[1]).max()) > 1e-6


def test_recurrent_unroll_and_learner_step():
    env = catch.make()
    tc = small_train(unroll_length=12, batch_size=8)
    init_fn, apply_fn, init_state = minatar_lstm_net(env.obs_shape,
                                                     env.num_actions)
    params, _ = init_agent(init_fn, jax.random.PRNGKey(0))
    opt = make_optimizer(tc)
    opt_state = opt.init(params)
    key = jax.random.PRNGKey(1)
    env_state, obs = rollout_lib.env_reset_batch(env, key, tc.batch_size)
    unroll = rollout_lib.make_recurrent_unroll(env, apply_fn, init_state,
                                               tc.unroll_length)
    carry = unroll.initial_carry(env_state, obs, tc.batch_size)
    train_step = learner_lib.make_recurrent_train_step(apply_fn, opt, tc)

    @jax.jit
    def combined(params, opt_state, step, carry, key):
        carry, ro = unroll(params, carry, key)
        params, opt_state, m = train_step(params, opt_state, step, ro)
        return params, opt_state, carry, m

    for step in range(3):
        key, k = jax.random.split(key)
        params, opt_state, carry, m = combined(
            params, opt_state, jnp.int32(step), carry, k)
        assert bool(jnp.isfinite(m["loss"]))


def test_recurrent_learner_reproduces_behavior_logits():
    """On-policy contract: the learner's re-run of the recurrence from the
    stored initial core_state must reproduce the actor's behavior logits
    exactly (same params)."""
    env = catch.make()
    tc = small_train(unroll_length=9, batch_size=4)
    init_fn, apply_fn, init_state = minatar_lstm_net(env.obs_shape,
                                                     env.num_actions)
    params, _ = init_agent(init_fn, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(2)
    env_state, obs = rollout_lib.env_reset_batch(env, key, tc.batch_size)
    unroll = rollout_lib.make_recurrent_unroll(env, apply_fn, init_state,
                                               tc.unroll_length)
    carry = unroll.initial_carry(env_state, obs, tc.batch_size)
    # run two unrolls so the second starts from carried state + done flags
    carry, _ = unroll(params, carry, jax.random.PRNGKey(3))
    carry, ro = unroll(params, carry, jax.random.PRNGKey(4))

    def relearn(core_state, obs_seq, pre_done):
        def step(cs, xs):
            o, d = xs
            out = apply_fn(params, o, cs, d)
            return out.core_state, out.policy_logits
        _, logits = jax.lax.scan(step, core_state, (obs_seq, pre_done))
        return logits

    logits = relearn(ro["core_state"], ro["obs"], ro["pre_done"])
    np.testing.assert_allclose(logits[:tc.unroll_length],
                               ro["behavior_logits"], rtol=1e-5, atol=1e-5)


def test_rollout_buffers_recycling():
    specs = rollout_specs((10, 5, 1), 3, unroll_length=4)
    rb = RolloutBuffers(specs, num_buffers=6)
    assert rb.qsizes() == {"free": 6, "full": 0}

    def actor(i):
        idx = rb.acquire(timeout=5)
        rb.write(idx, {
            "obs": np.full(specs["obs"][0], i, np.float32),
            "action": np.full((4,), i, np.int32),
            "behavior_logits": np.zeros((4, 3), np.float32),
            "reward": np.full((4,), float(i), np.float32),
            "done": np.zeros((4,), bool),
        })
        rb.commit(idx)

    threads = [threading.Thread(target=actor, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    batch = rb.get_batch(4, timeout=5)
    assert batch["obs"].shape == (5, 4, 10, 5, 1)
    assert batch["action"].shape == (4, 4)
    assert sorted(batch["reward"][0].tolist()) == [0.0, 1.0, 2.0, 3.0]
    # indices recycled
    assert rb.qsizes() == {"free": 6, "full": 0}


def test_rollout_buffers_backpressure():
    specs = {"x": ((2,), np.float32)}
    rb = RolloutBuffers(specs, num_buffers=2)
    rb.commit(rb.acquire())
    rb.commit(rb.acquire())
    import queue as q
    with pytest.raises(q.Empty):
        rb.acquire(timeout=0.05)  # blocked until the learner recycles
    rb.get_batch(2, timeout=1)
    assert rb.acquire(timeout=1) in (0, 1)
