"""Property-style spec tests for the logical-axes -> mesh mapping
(``logical_to_mesh`` / ``batch_axes_spec``), pinning the contract every
new mesh combination must obey. Seeded sweeps stand in for hypothesis, as
in test_moe.py — the suite runs on a bare install.

Properties (over every rules table, arbitrary 1-D/2-D/3-D meshes via
``jax.sharding.AbstractMesh`` — no real devices needed — and the full
heterogeneous arch zoo):
  * every produced PartitionSpec only names LIVE mesh axes;
  * no mesh axis is used twice within one spec;
  * with a shape given, a mapped dimension is always divisible by the
    product of its mesh-axis sizes (non-divisible mappings replicate);
  * ``batch_axes_spec`` shards exactly the batch dim over the data-like
    axes, or returns None (replicate) when non-divisible / size-1.
"""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_reduced_config
from repro.distributed.sharding import (RL_AGENT_RULES, RULE_SETS,
                                        batch_axes_spec, data_axes,
                                        logical_to_mesh)
from repro.models import model as model_lib
from repro.models.common import split_params

# logical-axis vocabulary: every axis name any rules table knows, minus
# "attn_pref" (a preference flag consumed by constrain_attention, never a
# parameter axis), plus names no table maps (must replicate).
_LOGICAL = sorted({ax for rules in RULE_SETS.values() for ax in rules}
                  - {"attn_pref"}) + ["layers", "unknown_axis"]
_RULES_NAMES = sorted(RULE_SETS)


def _mesh(data=1, model=1, pod=None):
    shape = (("data", data), ("model", model))
    if pod:
        shape = (("pod", pod),) + shape
    return jax.sharding.AbstractMesh(shape)


def _assert_valid(spec, mesh, shape=None):
    """The executable spec contract (module docstring)."""
    used = []
    for i, part in enumerate(spec):
        if part is None:
            continue
        axes = part if isinstance(part, tuple) else (part,)
        for a in axes:
            assert a in mesh.axis_names, f"{spec} names dead axis {a!r}"
            used.append(a)
        if shape is not None:
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert shape[i] % size == 0, \
                f"{spec}: dim {i} ({shape[i]}) not divisible by {size}"
    assert len(used) == len(set(used)), f"{spec} reuses a mesh axis"
    if shape is not None:
        assert len(spec) <= len(shape)


_MESHES = [_mesh(1, 1), _mesh(2, 1), _mesh(1, 2), _mesh(2, 2),
           _mesh(4, 2), _mesh(2, 4), _mesh(8, 1), _mesh(1, 16),
           _mesh(2, 16, pod=2), _mesh(16, 16)]


@pytest.mark.parametrize("seed", [0, 7, 101, 577, 1000])
def test_logical_to_mesh_properties_random_sweep(seed):
    """Random (rules, mesh, logical axes, shape) draws: the produced spec
    always satisfies the contract, with and without shape-aware dropping
    and with the fallback-model pass on."""
    rng = np.random.default_rng(seed)
    for _ in range(150):
        rules = RULE_SETS[_RULES_NAMES[rng.integers(len(_RULES_NAMES))]]
        mesh = _MESHES[rng.integers(len(_MESHES))]
        ndim = int(rng.integers(1, 5))
        axes = tuple(_LOGICAL[i]
                     for i in rng.integers(0, len(_LOGICAL), size=ndim))
        shape = tuple(int(rng.choice([1, 2, 3, 4, 6, 8, 16, 48, 56, 512]))
                      for _ in range(ndim))
        # axis-validity holds even without a shape (no divisibility pass)
        _assert_valid(logical_to_mesh(axes, mesh, rules), mesh)
        for fallback in (False, True):
            spec = logical_to_mesh(axes, mesh, rules, shape,
                                   fallback_model=fallback and ndim > 1)
            _assert_valid(spec, mesh, shape)


@pytest.mark.parametrize("seed", [0, 7, 101, 577, 1000])
def test_batch_axes_spec_properties_random_sweep(seed):
    """batch_axes_spec shards exactly the requested batch dim over the
    data-like axes, or replicates when the batch does not divide."""
    rng = np.random.default_rng(seed)
    for _ in range(150):
        rules = RULE_SETS[_RULES_NAMES[rng.integers(len(_RULES_NAMES))]]
        mesh = _MESHES[rng.integers(len(_MESHES))]
        ndim = int(rng.integers(1, 6))
        shape = tuple(int(rng.choice([1, 2, 3, 4, 6, 8, 16, 32, 64]))
                      for _ in range(ndim))
        bdim = int(rng.integers(0, ndim))
        spec = batch_axes_spec(mesh, rules, ndim, shape, bdim)
        daxes = data_axes(mesh)
        dsize = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
        if dsize == 1 or shape[bdim] % dsize != 0:
            assert spec is None
        else:
            parts = list(spec) + [None] * (ndim - len(spec))
            assert parts[bdim] == (daxes if len(daxes) > 1 else daxes[0])
            assert all(p is None for i, p in enumerate(parts) if i != bdim)
            _assert_valid(spec, mesh, shape)


# ---------------------------------------------------------------------------
# the real parameter trees: every arch config x rules table x mesh


_ARCHS = ["qwen3-4b", "mixtral-8x7b", "zamba2-2.7b", "xlstm-125m",
          "deepseek-coder-33b", "gemma2-27b", "llama-3.2-vision-90b",
          "granite-moe-1b-a400m"]


def _axes_shapes(cfg):
    box = {}

    def f():
        vals, axes = split_params(
            model_lib.model_init(jax.random.PRNGKey(0), cfg))
        box["axes"] = axes
        return vals

    shapes = jax.eval_shape(f)
    return box["axes"], shapes


@pytest.mark.parametrize("arch", _ARCHS)
def test_arch_param_specs_valid_on_every_mesh(arch):
    """Heterogeneous archs (grouped-KV attention, MoE, SSM, xLSTM, VLM
    cross-attention): every parameter's spec obeys the contract on every
    mesh under every rules table — kv_heads=2 on a 16-way model axis must
    replicate, never crash or shard unevenly."""
    cfg = get_reduced_config(arch)
    axes_tree, shapes_tree = _axes_shapes(cfg)
    is_axes = lambda x: isinstance(x, tuple) and all(  # noqa: E731
        isinstance(a, str) for a in x)
    ax_leaves = jax.tree.leaves(axes_tree, is_leaf=is_axes)
    sh_leaves = jax.tree.leaves(shapes_tree)
    assert len(ax_leaves) == len(sh_leaves) > 0
    for rules_name in ("megatron", "fsdp", "seqpar", "expert", "rl_agent"):
        rules = RULE_SETS[rules_name]
        for mesh in _MESHES:
            for ax, sh in zip(ax_leaves, sh_leaves):
                spec = logical_to_mesh(
                    ax, mesh, rules, sh.shape,
                    fallback_model=len(sh.shape) > 1)
                _assert_valid(spec, mesh, sh.shape)


def test_rl_agent_rules_on_2d_mesh():
    """RL_AGENT_RULES stay valid on the 2-D mesh: conv/fc params fully
    replicated (never touching "model"), batch over the data axes only."""
    mesh = _mesh(4, 2)
    for axes, shape in [(("conv_h", "conv_w", "conv_in", "conv_out"),
                         (3, 3, 32, 64)),
                        (("fc_in", "fc_out"), (288, 128))]:
        assert logical_to_mesh(axes, mesh, RL_AGENT_RULES, shape) == P()
    assert logical_to_mesh(("act_batch",), mesh, RL_AGENT_RULES, (64,)) \
        == P("data")
    assert batch_axes_spec(mesh, RL_AGENT_RULES, 2, (6, 9), 0) is None
    pod = _mesh(2, 2, pod=2)
    assert logical_to_mesh(("act_batch",), pod, RL_AGENT_RULES, (64,)) \
        == P(("pod", "data"))
