"""Pallas-kernel impl parity: the ``--attn-impl kernel`` / ``--ssd-impl
kernel`` paths agree with xla to 1e-5 (fp32, interpret mode on CPU).

Layers of the pyramid:
  * ``attn_apply`` fwd/bwd vs xla across GQA / MQA / sliding-window /
    softcap, and ``attn_decode`` against the ring-buffer cache;
  * whole-model fwd/bwd for every zoo arch with an attention or mamba
    mixer (xlstm-125m has neither and is excluded);
  * prefill -> decode roundtrip: kernel-impl serve_step logits vs the
    xla decode path from the same kernel-built cache;
  * end-to-end: per-step LM pretrain losses (the acceptance criterion)
    in-process, and under a ("data","model") mesh with 2 forced host
    devices in a subprocess (conftest.run_forced).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_forced
from repro.configs import ARCHS, get_reduced_config
from repro.models import attention as A
from repro.models import model as model_lib

TOL = dict(rtol=1e-5, atol=1e-5)


def _has_kernel_mixer(cfg):
    mixers = {m for m, _ in cfg.block_pattern}
    return bool(mixers & {"attn", "local_attn", "swa_attn", "xattn",
                          "mamba"}) or cfg.shared_attn_every > 0


KERNEL_ARCHS = [a for a in ARCHS
                if _has_kernel_mixer(get_reduced_config(a))]


def _kernel_cfg(cfg):
    return dataclasses.replace(cfg, attn_impl="kernel", ssd_impl="kernel")


# ---------------------------------------------------------------------------
# attn_apply: kernel vs xla, forward and backward
# ---------------------------------------------------------------------------

def _attn_setup(cfg, b=2, s=96, key=0):
    k = jax.random.PRNGKey(key)
    params = jax.tree.map(
        lambda p: p.value if hasattr(p, "value") else p,
        A.attn_init(k, cfg, "attn"),
        is_leaf=lambda x: hasattr(x, "value"))
    x = jax.random.normal(jax.random.fold_in(k, 1), (b, s, cfg.d_model),
                          jnp.float32)
    return params, x


@pytest.mark.parametrize("kind,softcap,kv_heads", [
    ("attn", None, 2),        # GQA
    ("attn", None, 1),        # MQA
    ("attn", 30.0, 2),        # softcap inside the kernel
    ("swa_attn", None, 2),    # sliding window inside the kernel
])
def test_attn_apply_kernel_matches_xla(kind, softcap, kv_heads):
    cfg = dataclasses.replace(get_reduced_config("qwen3-32b"),
                              attn_logit_softcap=softcap, sliding_window=48,
                              attn_chunk=32, num_kv_heads=kv_heads)
    params, x = _attn_setup(cfg)
    pos = jnp.arange(x.shape[1])

    def run(impl):
        def f(x):
            o, _ = A.attn_apply(params, x, cfg=cfg, kind=kind,
                                positions=pos, impl=impl)
            return jnp.mean(jnp.square(o.astype(jnp.float32))), o

        (loss, o), g = jax.value_and_grad(f, has_aux=True)(x)
        return o, g

    o_ref, g_ref = run("xla")
    o_k, g_k = run("kernel")
    np.testing.assert_allclose(o_ref, o_k, **TOL)
    np.testing.assert_allclose(g_ref, g_k, **TOL)


def test_attn_decode_kernel_ring_buffer():
    """Kernel decode equals xla decode at every step, through the
    ring-buffer wrap of a window-sized cache."""
    cfg = dataclasses.replace(get_reduced_config("qwen3-32b"),
                              sliding_window=16, attn_chunk=16)
    params, x = _attn_setup(cfg, b=1, s=40)
    for kind in ("swa_attn", "attn"):
        caches = {"xla": A.attn_cache_init(cfg, kind, 1, 40, x.dtype),
                  "kernel": A.attn_cache_init(cfg, kind, 1, 40, x.dtype)}
        for t in range(40):
            outs = {}
            for impl in ("xla", "kernel"):
                outs[impl], caches[impl] = A.attn_decode(
                    params, x[:, t:t + 1], caches[impl], cfg=cfg,
                    kind=kind, pos=jnp.int32(t), impl=impl)
            np.testing.assert_allclose(outs["xla"], outs["kernel"], **TOL)


# ---------------------------------------------------------------------------
# whole-model fwd/bwd parity for every arch with a kernel-served mixer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", KERNEL_ARCHS)
def test_model_fwd_bwd_kernel_parity(arch):
    cfg = get_reduced_config(arch)
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 32)),
        jnp.int32)
    params, _ = model_lib.init(jax.random.PRNGKey(0), cfg)

    def run(cfg, impl):
        def loss(params):
            h, _, _ = model_lib.forward(params, tokens, cfg=cfg, impl=impl)
            return jnp.mean(jnp.square(h.astype(jnp.float32)))

        val, g = jax.value_and_grad(loss)(params)
        return val, g

    v_ref, g_ref = run(cfg, "xla")
    v_k, g_k = run(_kernel_cfg(cfg), "kernel")
    np.testing.assert_allclose(np.asarray(v_ref), np.asarray(v_k), **TOL)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), **TOL), g_ref, g_k)


# ---------------------------------------------------------------------------
# prefill -> decode roundtrip on the kernel path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen3-4b", "gemma2-27b", "mixtral-8x7b",
                                  "zamba2-2.7b"])
def test_prefill_decode_roundtrip_kernel(arch):
    """Kernel prefill builds the same caches as xla prefill, and kernel
    serve_step tracks xla serve_step token by token from that cache."""
    cfg = get_reduced_config(arch)
    cfg_k = _kernel_cfg(cfg)
    P, N = 16, 8
    tokens = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (2, P + N)),
        jnp.int32)
    params, _ = model_lib.init(jax.random.PRNGKey(0), cfg)

    _, _, cache_ref = model_lib.prefill(params, tokens[:, :P], cfg=cfg,
                                        impl="xla", cache_seq_len=P + N)
    _, _, cache_k = model_lib.prefill(params, tokens[:, :P], cfg=cfg_k,
                                      impl="kernel", cache_seq_len=P + N)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), **TOL), cache_ref, cache_k)

    for t in range(P, P + N):
        lg_ref, _, cache_ref = model_lib.serve_step(
            params, tokens[:, t:t + 1], cache_ref, jnp.int32(t), cfg=cfg,
            impl="xla")
        lg_k, _, cache_k = model_lib.serve_step(
            params, tokens[:, t:t + 1], cache_k, jnp.int32(t), cfg=cfg_k,
            impl="kernel")
        np.testing.assert_allclose(np.asarray(lg_ref), np.asarray(lg_k),
                                   **TOL)


# ---------------------------------------------------------------------------
# end-to-end: per-step LM pretrain losses (the acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen3-4b", "zamba2-2.7b"])
def test_lm_pretrain_loss_parity_kernel(arch):
    from repro.configs.base import TrainConfig
    from repro.core import learner as L
    from repro.optim import make_optimizer

    cfg = get_reduced_config(arch)
    tc = TrainConfig(optimizer="adamw", learning_rate=1e-3, grad_clip=1.0,
                     lr_schedule="constant")
    params0, _ = model_lib.init(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer(tc)
    rng = np.random.default_rng(0)
    B, S = 4, 32
    batches = [{"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32)}
        for _ in range(3)]

    def losses(cfg):
        step = jax.jit(L.make_lm_pretrain_step(cfg, opt, loss_chunk=S))
        params, opt_state = params0, opt.init(params0)
        out = []
        for s, b in enumerate(batches):
            params, opt_state, m = step(params, opt_state, jnp.int32(s), b)
            out.append(float(m["loss"]))
        return out

    l_ref = losses(dataclasses.replace(cfg, attn_impl="xla"))
    l_k = losses(_kernel_cfg(cfg))
    np.testing.assert_allclose(l_ref, l_k, **TOL)


# ---------------------------------------------------------------------------
# sharded parity: kernel impl under a ("data","model") mesh, forced devices
# ---------------------------------------------------------------------------

_MESH_KERNEL_SCRIPT = r"""
import dataclasses

import jax, jax.numpy as jnp
import numpy as np

jax.config.update("jax_default_matmul_precision", "highest")

from repro.configs import get_reduced_config
from repro.configs.base import TrainConfig
from repro.core import learner as L
from repro.distributed import sharding as shd
from repro.launch.mesh import make_mesh2d
from repro.models import model as M
from repro.optim import make_optimizer

B, S = 4, 32
cfg = get_reduced_config("qwen3-4b")
tc = TrainConfig(optimizer="adamw", learning_rate=1e-3, grad_clip=1.0,
                 lr_schedule="constant")
params0, axes = M.init(jax.random.PRNGKey(0), cfg)
opt = make_optimizer(tc)
rng = np.random.default_rng(0)
batches = [{"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                               (B, S + 1)), jnp.int32)}
           for _ in range(3)]


def losses(mesh, attn_impl, carry):
    icfg = dataclasses.replace(cfg, attn_impl=attn_impl)
    if mesh is None:
        params, gc, rules = params0, None, None
    else:
        rules = shd.MEGATRON_RULES
        pshard = shd.param_shardings(axes, mesh, rules, params0)
        gc = lambda g: jax.tree.map(jax.lax.with_sharding_constraint, g,
                                    pshard)
        params = jax.device_put(params0, pshard)
    step = jax.jit(L.make_lm_pretrain_step(icfg, opt, loss_chunk=S,
                                           grad_constraint=gc, mesh=mesh,
                                           rules=rules))
    opt_state0 = opt.init(params)
    opt_state, out = opt_state0, []
    for s, b in enumerate(batches):
        if carry:
            params, opt_state, m = step(params, opt_state, jnp.int32(s), b)
        else:
            _, _, m = step(params, opt_state0, jnp.int32(0), b)
        out.append(float(m["loss"]))
    return out


mesh = make_mesh2d(1, 2)  # --mesh-model 2
# per-step program parity from identical params: 1e-5
s_ref = losses(None, "xla", carry=False)
s_k = losses(mesh, "kernel", carry=False)
print("per-step xla unmeshed ", s_ref)
print("per-step kernel mesh12", s_k)
np.testing.assert_allclose(s_ref, s_k, rtol=1e-5, atol=1e-5)
# 3-step trajectory: reduction-order noise compounds through adamw
l_ref = losses(None, "xla", carry=True)
l_k = losses(mesh, "kernel", carry=True)
print("trajectory xla unmeshed ", l_ref)
print("trajectory kernel mesh12", l_k)
np.testing.assert_allclose(l_ref, l_k, rtol=1e-4, atol=1e-4)
print("KERNEL MESH PARITY OK")
"""


def test_kernel_mesh_model2_parity_subprocess():
    proc = run_forced(script=_MESH_KERNEL_SCRIPT, devices=2)
    assert "KERNEL MESH PARITY OK" in proc.stdout
