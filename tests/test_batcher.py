"""DynamicBatcher / BatchingQueue semantics (PolyBeast batcher.cc port)."""

import threading
import time

import numpy as np

from repro.core.batcher import (BatchingQueue, Closed, DynamicBatcher,
                                bucket_size, stack_trees, unstack_tree)


def test_dynamic_batcher_batches_and_scatters():
    b = DynamicBatcher(max_batch_size=4, timeout_ms=50, pad_to_bucket=False)
    results = {}

    def actor(i):
        results[i] = b.compute(np.full((3,), i, np.float32))

    threads = [threading.Thread(target=actor, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    got = None
    while got is None:
        got = b.get_batch(timeout=1.0)
    inputs, respond, n = got
    assert n == 4 and inputs.shape == (4, 3)
    respond(inputs * 10.0)  # consumer reply
    for t in threads:
        t.join(timeout=5)
    for i in range(4):
        np.testing.assert_allclose(results[i], np.full((3,), i * 10.0))


def test_dynamic_batcher_timeout_partial_batch():
    b = DynamicBatcher(max_batch_size=8, timeout_ms=10, pad_to_bucket=True)
    out = {}

    def actor():
        out["r"] = b.compute(np.ones((2,), np.float32))

    t = threading.Thread(target=actor)
    t.start()
    inputs, respond, n = b.get_batch(timeout=2.0)
    assert n == 1
    assert inputs.shape[0] == bucket_size(1)  # padded to the bucket ladder
    respond(inputs + 1)
    t.join(timeout=5)
    np.testing.assert_allclose(out["r"], np.full((2,), 2.0))


def test_dynamic_batcher_close_unblocks_actors():
    b = DynamicBatcher(max_batch_size=4, timeout_ms=10)
    errs = []

    def actor():
        try:
            b.compute(np.zeros(1, np.float32))
        except Closed:
            errs.append("closed")

    t = threading.Thread(target=actor)
    t.start()
    time.sleep(0.05)
    b.close()
    t.join(timeout=5)
    assert errs == ["closed"]


def test_batching_queue_stacks_batch_dim():
    q = BatchingQueue(batch_size=3, batch_dim=1)
    for i in range(3):
        q.put({"x": np.full((5, 2), i, np.float32)})
    batch = q.get(timeout=1)
    assert batch["x"].shape == (5, 3, 2)
    np.testing.assert_allclose(batch["x"][0, :, 0], [0, 1, 2])


def test_batching_queue_close_stops_iteration():
    q = BatchingQueue(batch_size=2)
    q.put(np.zeros(1))
    q.close()
    assert list(q) == []


def test_bucket_ladder():
    assert bucket_size(1) == 1
    assert bucket_size(3) == 4
    assert bucket_size(100) == 128
    assert bucket_size(300) == 300


def test_stack_unstack_roundtrip():
    trees = [{"a": np.ones(3) * i, "b": np.zeros((2, 2))} for i in range(4)]
    stacked = stack_trees(trees, axis=0)
    back = unstack_tree(stacked, 4, axis=0)
    for i in range(4):
        np.testing.assert_allclose(back[i]["a"], trees[i]["a"])
