"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _qkv(rng, b, h, kh, s, hd, dtype):
    q = jnp.asarray(rng.normal(0, 1, (b, h, s, hd)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (b, kh, s, hd)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (b, kh, s, hd)), dtype)
    return q, k, v


FLASH_CASES = [
    # b, h, kh, s, hd, window, softcap, dtype
    (2, 4, 2, 256, 64, 0, 0.0, jnp.float32),
    (1, 8, 8, 128, 128, 0, 0.0, jnp.float32),      # MHA
    (2, 4, 1, 256, 64, 0, 0.0, jnp.float32),       # MQA
    (1, 4, 2, 256, 64, 64, 0.0, jnp.float32),      # sliding window
    (1, 4, 2, 128, 64, 0, 50.0, jnp.float32),      # softcap (gemma)
    (1, 4, 2, 192, 64, 0, 0.0, jnp.float32),       # non-pow2 seq
    (2, 4, 2, 256, 64, 0, 0.0, jnp.bfloat16),      # low precision
]


@pytest.mark.parametrize("b,h,kh,s,hd,win,cap,dtype", FLASH_CASES)
def test_flash_attention_sweep(b, h, kh, s, hd, win, cap, dtype):
    rng = np.random.default_rng(hash((b, h, s, hd)) % 2**31)
    q, k, v = _qkv(rng, b, h, kh, s, hd, dtype)
    out = ops.flash_attention(q, k, v, window=win, softcap=cap,
                              block_q=64, block_k=64)
    exp = ref.ref_flash_attention(q, k, v, window=win, softcap=cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(out.astype(jnp.float32),
                               exp.astype(jnp.float32), rtol=tol, atol=tol)


def test_flash_non_causal():
    rng = np.random.default_rng(0)
    q, k, v = _qkv(rng, 1, 4, 2, 128, 64, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    exp = ref.ref_flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(out, exp, rtol=2e-5, atol=2e-5)


DECODE_CASES = [
    # b, h, kh, s, hd, window, pos_frac
    (2, 8, 2, 256, 64, 0, 0.6),
    (1, 4, 4, 128, 128, 0, 0.99),
    (1, 8, 1, 256, 64, 0, 0.2),
    (2, 4, 2, 128, 64, 64, 0.9),
]


@pytest.mark.parametrize("b,h,kh,s,hd,win,pf", DECODE_CASES)
def test_decode_attention_sweep(b, h, kh, s, hd, win, pf):
    rng = np.random.default_rng(hash((b, h, s)) % 2**31)
    q = jnp.asarray(rng.normal(0, 1, (b, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, kh, s, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, kh, s, hd)), jnp.float32)
    pos = jnp.int32(int(pf * (s - 1)))
    slot = jnp.arange(s, dtype=jnp.int32)
    out = ops.decode_attention(q, k, v, slot, pos, window=win, block_k=64)
    exp = ref.ref_decode_attention(q, k, v, slot, pos, window=win)
    np.testing.assert_allclose(out, exp, rtol=2e-5, atol=2e-5)


def test_decode_ring_buffer_slots():
    """Slot positions from a wrapped ring buffer (non-monotonic)."""
    rng = np.random.default_rng(1)
    b, h, kh, s, hd = 1, 4, 2, 64, 64
    q = jnp.asarray(rng.normal(0, 1, (b, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, kh, s, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, kh, s, hd)), jnp.float32)
    pos = jnp.int32(100)
    idx = jnp.arange(s)
    slot = pos - jnp.mod(pos - idx, s)  # ring semantics
    out = ops.decode_attention(q, k, v, slot, pos, window=s, block_k=32)
    exp = ref.ref_decode_attention(q, k, v, slot, pos, window=s)
    np.testing.assert_allclose(out, exp, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("t,b", [(1, 128), (80, 256), (33, 384), (200, 128)])
def test_vtrace_kernel_sweep(t, b):
    rng = np.random.default_rng(t * 1000 + b)
    deltas = jnp.asarray(rng.normal(0, 1, (t, b)), jnp.float32)
    dcs = jnp.asarray(rng.random((t, b)) * 0.99, jnp.float32)
    out = ops.vtrace_acc(deltas, dcs)
    exp = ref.ref_vtrace_scan(deltas, dcs)
    np.testing.assert_allclose(out, exp, rtol=1e-6, atol=1e-6)


def test_flash_attention_matches_model_path():
    """The kernel agrees with the model's own dense attention math (GQA
    layout translation: flat-H model layout vs (B,H,S,hd) kernel layout)."""
    from repro.models import attention as A
    rng = np.random.default_rng(5)
    b, h, kh, s, hd = 1, 4, 2, 128, 64
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, s, kh, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, s, kh, hd)), jnp.float32)
    pos = jnp.arange(s)
    ke, ve = A._expand_kv(k, h // kh), A._expand_kv(v, h // kh)
    dense = A._attend_dense(q, ke, ve, pos, pos, hd ** -0.5, 0, None, True)
    out = ops.flash_attention(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), block_q=64,
                              block_k=64)
    np.testing.assert_allclose(out.transpose(0, 2, 1, 3), dense,
                               rtol=2e-5, atol=2e-5)


SSD_CASES = [
    # bh, L, N, P
    (4, 64, 32, 32),
    (2, 128, 64, 64),
    (1, 128, 128, 64),
    (3, 96, 64, 32),   # non-pow2 chunk
]


@pytest.mark.parametrize("bh,l,n,p", SSD_CASES)
def test_ssd_chunk_kernel_sweep(bh, l, n, p):
    rng = np.random.default_rng(hash((bh, l, n, p)) % 2**31)
    c = jnp.asarray(rng.normal(0, 1, (bh, l, n)), jnp.float32)
    b = jnp.asarray(rng.normal(0, 1, (bh, l, n)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (bh, l, p)), jnp.float32)
    da = jnp.asarray(-rng.random((bh, l, 1)) * 0.1, jnp.float32)
    h = jnp.asarray(rng.normal(0, 1, (bh, p, n)), jnp.float32)
    y, hn = ops.ssd_chunk(c, b, x, da, h)
    yr, hr = ref.ref_ssd_chunk(c, b, x, da, h)
    np.testing.assert_allclose(y, yr, rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(hn, hr, rtol=3e-5, atol=3e-5)


def test_ssd_chunk_matches_model_mamba():
    """The SSD kernel agrees with models/mamba.py's chunk_step math: feed
    one chunk through both and compare y and the updated state."""
    import dataclasses
    from repro.configs import get_reduced_config
    from repro.models import mamba
    from repro.models.common import split_params
    cfg = dataclasses.replace(get_reduced_config("zamba2-2.7b"),
                              ssm_chunk=16)
    params = split_params(mamba.mamba_init(jax.random.PRNGKey(0), cfg))[0]
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y_model, st = mamba.mamba_apply(params, x, cfg, return_state=True)

    # recompute the kernel path from the same pre-activations
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    n_ = cfg.ssm_state
    xs = x @ params["in_proj_x"]
    bc = x @ params["in_proj_bc"]
    dt = jax.nn.softplus(x @ params["in_proj_dt"] + params["dt_bias"])
    conv_in = jnp.concatenate([xs, bc], axis=-1)
    conv_out, _ = mamba._conv1d(conv_in, params["conv_w"], params["conv_b"])
    xs, bmat, cmat = jnp.split(conv_out, [d_in, d_in + n_], axis=-1)
    a = -jnp.exp(params["a_log"])
    da = (dt * a)  # (B, L, H)

    bsz, L = 2, 16
    p_ = cfg.ssm_head_dim
    xh = (xs.reshape(bsz, L, nh, p_) * dt[..., None])
    # flatten (B, H) -> BH with per-head B/C shared across heads
    c_k = jnp.repeat(cmat[:, None], nh, 1).reshape(bsz * nh, L, n_)
    b_k = jnp.repeat(bmat[:, None], nh, 1).reshape(bsz * nh, L, n_)
    x_k = xh.transpose(0, 2, 1, 3).reshape(bsz * nh, L, p_)
    da_k = da.transpose(0, 2, 1).reshape(bsz * nh, L, 1)
    h0 = jnp.zeros((bsz * nh, p_, n_), jnp.float32)
    y_k, h_k = ops.ssd_chunk(c_k, b_k, x_k, da_k, h0)
    # model state layout: (B, H, P, N)
    np.testing.assert_allclose(
        h_k.reshape(bsz, nh, p_, n_), st["ssm"], rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("arch", ["qwen3-32b", "gemma2-27b", "mixtral-8x7b"])
def test_model_end_to_end_with_pallas_attention(arch):
    """The whole decoder with attn_impl='pallas' (kernel in interpret mode)
    matches the XLA attention path — kernels are drop-in at model level."""
    import dataclasses
    from repro.configs import get_reduced_config
    from repro.models import model as M
    cfg = dataclasses.replace(get_reduced_config(arch), attn_chunk=64)
    params, _ = M.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                cfg.vocab_size)
    a, _, _ = M.apply_lm(params, tokens, cfg=cfg, impl="xla")
    b, _, _ = M.apply_lm(params, tokens, cfg=cfg, impl="pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                               atol=2e-3)
