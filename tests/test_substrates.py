"""Substrate tests: envs (seeded invariant sweeps), optimizers, checkpoint,
sharding rules, data pipeline."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.data import PackedBatchIterator, markov_corpus, rl_episode_batch
from repro.envs import catch, gridworld, token_mdp
from repro.optim import adamw, apply_updates, clip_by_global_norm, rmsprop, sgd


# ---------------------------------------------------------------------------
# envs
# ---------------------------------------------------------------------------

# Seeded sweep standing in for the former hypothesis property test, so the
# suite runs on a bare install (hypothesis is an optional extra).
@pytest.mark.parametrize("mk", [catch.make, gridworld.make,
                                lambda: token_mdp.make(64)])
@pytest.mark.parametrize("seed,steps", [(0, 1), (12345, 7), (2**19, 25),
                                        (2**20, 40)])
def test_env_invariants(mk, seed, steps):
    env = mk()
    key = jax.random.PRNGKey(seed)
    state, obs = env.reset(key)
    assert obs.shape == env.obs_shape
    step = jax.jit(env.step)
    for i in range(steps):
        key, ka, ks = jax.random.split(key, 3)
        action = jax.random.randint(ka, (), 0, env.num_actions)
        state, obs, reward, done = step(state, action, ks)
        assert obs.shape == env.obs_shape
        assert bool(jnp.isfinite(reward))
        assert reward.dtype == jnp.float32


def test_catch_optimal_policy_always_wins():
    """Moving the paddle toward the ball catches every episode."""
    env = catch.make()
    key = jax.random.PRNGKey(0)
    state, obs = env.reset(key)
    total, episodes = 0.0, 0
    for i in range(200):
        # locate ball and paddle from the observation
        grid = np.asarray(obs[..., 0])
        ball = np.argwhere(grid[:-1] > 0)       # (k, 2): row, col
        paddle = np.argwhere(grid[-1] > 0)      # (k, 1): col
        if len(ball) and len(paddle):
            dx = int(np.sign(ball[0][1] - paddle[0][0]))
        else:
            dx = 0
        key, ks = jax.random.split(key)
        state, obs, reward, done = env.step(state, jnp.int32(dx + 1), ks)
        if bool(done):
            episodes += 1
            total += float(reward)
    assert episodes > 10
    assert total == episodes  # every episode caught


def test_token_mdp_reward_rule():
    env = token_mdp.make(32, a=5, b=3)
    key = jax.random.PRNGKey(0)
    state, obs = env.reset(key)
    correct = (5 * int(obs) + 3) % 32
    state, obs2, r, done = env.step(state, jnp.int32(correct), key)
    assert float(r) == 1.0
    state, _, r2, _ = env.step(state, jnp.int32((int(obs2) * 5 + 4) % 32),
                               key)
    assert float(r2) == 0.0


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("opt", [
    sgd(0.1), rmsprop(0.1, grad_clip=5.0), adamw(0.05, grad_clip=None)])
def test_optimizer_decreases_quadratic(opt):
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.5)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"])) + jnp.square(p["b"])

    l0 = float(loss(params))
    for step in range(60):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params, jnp.int32(step))
        params = apply_updates(params, upd)
    assert float(loss(params)) < 0.2 * l0


def test_global_norm_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip():
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "opt": [{"m": jnp.ones(4)}], "step": jnp.int32(7)}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "step_7")
        ckpt.save(path, tree, {"step": 7})
        restored, meta = ckpt.restore(path, tree)
        assert meta["step"] == 7
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert ckpt.latest_step_path(d) == path


def test_checkpoint_shape_mismatch_rejected():
    tree = {"w": jnp.ones((2, 2))}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "c.npz")
        ckpt.save(path, tree)
        with pytest.raises(ValueError):
            ckpt.restore(path, {"w": jnp.ones((3, 3))})


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_spec_for_divisibility_and_fallback():
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import MEGATRON_RULES, spec_for
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("model",))
    # trivially divisible on a 1-way mesh
    assert spec_for(("embed", "heads"), mesh, MEGATRON_RULES,
                    (64, 8)) == P(None, "model")


def test_zero1_adds_data_axis():
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import MEGATRON_RULES, zero1_shardings
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    axes = {"w": ("embed", "mlp")}
    shapes = {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)}
    sh = zero1_shardings(axes, shapes, mesh, MEGATRON_RULES)
    assert sh["w"].spec == P("data", "model")


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_markov_corpus_learnable_structure():
    c = markov_corpus(64, 5000, seed=0, branching=2)
    assert c.min() >= 0 and c.max() < 64
    # branching=2 => each token has at most 2 successors
    succ = {}
    for a, b in zip(c[:-1], c[1:]):
        succ.setdefault(int(a), set()).add(int(b))
    assert max(len(v) for v in succ.values()) <= 2


def test_packed_iterator_shapes():
    it = PackedBatchIterator(markov_corpus(32, 2000), batch_size=4,
                             seq_len=16)
    try:
        b = next(it)
        assert b["tokens"].shape == (4, 17)
        assert b["tokens"].dtype == np.int32
    finally:
        it.close()


def test_rl_episode_batch_rewards_match_rule():
    rng = np.random.default_rng(0)
    b = rl_episode_batch(rng, 4, 8, 32, a=5, b=3)
    target = (5 * b["tokens"][:, :-1] + 3) % 32
    np.testing.assert_array_equal(
        b["reward"], (b["tokens"][:, 1:] == target).astype(np.float32))
    assert b["done"][:, -1].all()
