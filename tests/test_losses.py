"""IMPALA loss properties + chunked-vocab head equivalence."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses


def test_chunked_logprob_matches_dense():
    rng = np.random.default_rng(0)
    b, s, d, v = 3, 32, 16, 40
    hidden = jnp.asarray(rng.normal(0, 1, (b, s, d)), jnp.float32)
    unembed = jnp.asarray(rng.normal(0, 1, (d, v)), jnp.float32)
    actions = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    lp, ent = losses.chunked_logprob_entropy(hidden, unembed, actions,
                                             chunk=8)
    logits = hidden @ unembed
    ref_lp = jax.nn.log_softmax(logits, -1)
    ref = jnp.take_along_axis(ref_lp, actions[..., None], -1)[..., 0]
    ref_ent = -jnp.sum(jnp.exp(ref_lp) * ref_lp, -1)
    np.testing.assert_allclose(lp, ref, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(ent, ref_ent, rtol=2e-5, atol=2e-5)


def test_chunked_grads_match_dense():
    rng = np.random.default_rng(1)
    b, s, d, v = 2, 16, 8, 20
    hidden = jnp.asarray(rng.normal(0, 1, (b, s, d)), jnp.float32)
    unembed = jnp.asarray(rng.normal(0, 1, (d, v)), jnp.float32)
    actions = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)

    def f_chunk(h):
        lp, ent = losses.chunked_logprob_entropy(h, unembed, actions,
                                                 chunk=4)
        return jnp.sum(lp) + 0.1 * jnp.sum(ent)

    def f_dense(h):
        logits = h @ unembed
        lp = jax.nn.log_softmax(logits, -1)
        alp = jnp.take_along_axis(lp, actions[..., None], -1)[..., 0]
        ent = -jnp.sum(jnp.exp(lp) * lp, -1)
        return jnp.sum(alp) + 0.1 * jnp.sum(ent)

    np.testing.assert_allclose(jax.grad(f_chunk)(hidden),
                               jax.grad(f_dense)(hidden),
                               rtol=3e-5, atol=3e-5)


def _batch(rng, t, b, a):
    return dict(
        target_logits=jnp.asarray(rng.normal(0, 1, (t, b, a)), jnp.float32),
        behavior_logits=jnp.asarray(rng.normal(0, 1, (t, b, a)),
                                    jnp.float32),
        actions=jnp.asarray(rng.integers(0, a, (t, b)), jnp.int32),
        rewards=jnp.asarray(rng.normal(0, 1, (t, b)), jnp.float32),
        discounts=jnp.asarray((rng.random((t, b)) > 0.1) * 0.99,
                              jnp.float32),
        values=jnp.asarray(rng.normal(0, 1, (t, b)), jnp.float32),
        bootstrap=jnp.asarray(rng.normal(0, 1, (b,)), jnp.float32),
    )


def test_logits_and_logprob_paths_agree():
    """The paper-faithful full-logits path and the LLM chosen-logprob path
    compute the same pg/baseline losses for the same data."""
    rng = np.random.default_rng(2)
    d = _batch(rng, 7, 5, 9)
    out_a = losses.impala_loss_from_logits(
        d["target_logits"], d["behavior_logits"], d["actions"], d["rewards"],
        d["discounts"], d["values"], d["bootstrap"])

    tl = jax.nn.log_softmax(d["target_logits"], -1)
    target_lp = jnp.take_along_axis(tl, d["actions"][..., None], -1)[..., 0]
    target_ent = -jnp.sum(jnp.exp(tl) * tl, -1)
    bl = jax.nn.log_softmax(d["behavior_logits"], -1)
    behavior_lp = jnp.take_along_axis(bl, d["actions"][..., None],
                                      -1)[..., 0]
    out_b = losses.impala_loss_from_logprobs(
        target_lp, target_ent, behavior_lp, d["rewards"], d["discounts"],
        d["values"], d["bootstrap"])
    np.testing.assert_allclose(out_a.pg_loss, out_b.pg_loss, rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(out_a.baseline_loss, out_b.baseline_loss,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out_a.entropy_loss, out_b.entropy_loss,
                               rtol=1e-5, atol=1e-5)


def test_entropy_gradient_flattens_policy():
    """Following the entropy term's gradient must increase entropy."""
    rng = np.random.default_rng(3)
    d = _batch(rng, 5, 4, 6)

    def ent_loss(logits):
        return losses.impala_loss_from_logits(
            logits, d["behavior_logits"], d["actions"], d["rewards"],
            d["discounts"], d["values"], d["bootstrap"],
            baseline_cost=0.0, entropy_cost=1.0).entropy_loss

    logits = d["target_logits"]
    g = jax.jit(jax.grad(ent_loss))
    for _ in range(200):
        logits = logits - 0.5 * g(logits)
    p = jax.nn.softmax(logits, -1)
    ent0 = -jnp.sum(jax.nn.softmax(d["target_logits"], -1)
                    * jax.nn.log_softmax(d["target_logits"], -1), -1).mean()
    ent = -jnp.sum(p * jnp.log(p + 1e-9), -1).mean()
    assert float(ent) > float(ent0) + 0.1  # strictly flatter
    assert float(ent) > 0.85 * np.log(6)


def test_baseline_gradient_moves_values_toward_vs():
    rng = np.random.default_rng(4)
    d = _batch(rng, 5, 4, 6)

    def bl(values):
        return losses.impala_loss_from_logits(
            d["target_logits"], d["behavior_logits"], d["actions"],
            d["rewards"], d["discounts"], values, d["bootstrap"],
            baseline_cost=1.0, entropy_cost=0.0).baseline_loss

    v = d["values"]
    l0 = float(bl(v))
    for _ in range(50):
        v = v - 0.1 * jax.grad(bl)(v)
    assert float(bl(v)) < 0.5 * l0
