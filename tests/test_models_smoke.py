"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each assigned family runs one forward and one IMPALA train step on CPU,
asserting output shapes and finiteness; plus prefill+decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_reduced_config
from repro.configs.base import TrainConfig
from repro.core import learner as learner_lib
from repro.models import model as M
from repro.optim import make_optimizer


def _inputs(cfg, key, b=2, s=16):
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    vision = None
    if cfg.vision_seq:
        vision = jax.random.normal(key, (b, cfg.vision_seq, cfg.d_model),
                                   jnp.float32)
    return tokens, vision


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_counts(arch):
    """The full (published) config is registered with the exact assigned
    numbers; params are in a sane range (exercised via dry-run only)."""
    cfg = get_config(arch)
    assert cfg.num_layers >= 12
    assert cfg.param_count() > 50e6


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = get_reduced_config(arch)
    assert cfg.d_model <= 512 and cfg.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params, axes = M.init(key, cfg)
    tokens, vision = _inputs(cfg, key)
    logits, baseline, aux = jax.jit(
        lambda p, t, v: M.apply_lm(p, t, cfg=cfg, vision=v))(
        params, tokens, vision)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert baseline.shape == (2, 16)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(baseline).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_reduced_config(arch)
    tc = TrainConfig(optimizer="adamw", learning_rate=1e-3, grad_clip=1.0,
                     lr_schedule="constant")
    key = jax.random.PRNGKey(1)
    params, _ = M.init(key, cfg)
    opt = make_optimizer(tc)
    opt_state = opt.init(params)
    step_fn = jax.jit(learner_lib.make_lm_train_step(cfg, opt, tc,
                                                     loss_chunk=16))
    b, s = 2, 16
    tokens = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)
    batch = {
        "tokens": tokens,
        "behavior_logprob": jnp.full((b, s), -np.log(cfg.vocab_size)),
        "reward": jax.random.normal(key, (b, s)),
        "done": jnp.zeros((b, s), bool).at[:, -1].set(True),
    }
    if cfg.vision_seq:
        batch["vision"] = jax.random.normal(
            key, (b, cfg.vision_seq, cfg.d_model), jnp.float32)
    params2, opt_state, m = step_fn(params, opt_state, jnp.int32(0), batch)
    assert bool(jnp.isfinite(m["loss"]))
    # parameters actually moved
    moved = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x))),
        jax.tree.map(lambda a, b_: a - b_, params, params2), 0.0)
    assert moved > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_reduced_config(arch)
    key = jax.random.PRNGKey(2)
    params, _ = M.init(key, cfg)
    b, s = 2, 15
    tokens, vision = _inputs(cfg, key, b, s + 1)
    full, _, _ = M.apply_lm(params, tokens, cfg=cfg, vision=vision)
    _, _, cache = M.prefill(params, tokens[:, :s], cfg=cfg, vision=vision,
                            cache_seq_len=s + 4)
    dec, _, _ = M.serve_step(params, tokens[:, s:s + 1], cache,
                             jnp.int32(s), cfg=cfg)
    np.testing.assert_allclose(full[:, s], dec[:, 0], rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["gemma2-27b", "mixtral-8x7b"])
def test_sliding_window_ring_buffer(arch):
    """Decode far past the window: ring-buffer cache must agree with the
    full forward pass (windowed attention)."""
    cfg = get_reduced_config(arch)  # window = 32
    key = jax.random.PRNGKey(3)
    params, _ = M.init(key, cfg)
    b, prefix, extra = 1, 47, 4
    total = prefix + extra
    tokens = jax.random.randint(key, (b, total + 1), 0, cfg.vocab_size)
    full, _, _ = M.apply_lm(params, tokens, cfg=cfg)
    _, _, cache = M.prefill(params, tokens[:, :prefix], cfg=cfg,
                            cache_seq_len=total + 1)
    for i in range(extra + 1):
        dec, _, cache = M.serve_step(params, tokens[:, prefix + i:
                                                    prefix + i + 1],
                                     cache, jnp.int32(prefix + i), cfg=cfg)
        np.testing.assert_allclose(full[:, prefix + i], dec[:, 0],
                                   rtol=3e-3, atol=3e-3)
