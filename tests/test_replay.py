"""Replay subsystem contract tests (core/replay.py + ReplaySource):

* capacity eviction order per strategy (FIFO vs lowest-priority-first),
* priority update after a real learner step (elite feedback loop),
* mixed fresh+replayed batches stay valid under ``check_rollout`` and
  ``ReplaySource`` satisfies the ``RolloutSource`` protocol,
* determinism under a fixed seed,
* slot-index leak regressions: ``RolloutBuffers.get_batch`` dying
  mid-batch, malformed inserts, and ``ReplaySource.stop()`` all return
  slot indices to the free list.
"""

import queue

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.atari_impala import small_train
from repro.core import learner as learner_lib
from repro.core import losses
from repro.core.replay import (AttentiveReplay, EliteReplay, ReplayBuffer,
                               UniformReplay, make_buffer)
from repro.core.rollout_buffers import RolloutBuffers
from repro.core.runtime import Runtime
from repro.core.sources import (DeviceSource, ReplaySource, RolloutSource,
                                check_rollout)
from repro.envs import catch
from repro.models.convnet import init_agent, minatar_net
from repro.optim import make_optimizer

T, B, A = 4, 3, 3
OBS = (2, 2, 1)


def make_rollout(ids, t=T, num_actions=A, seed=0):
    """A canonical time-major rollout batch whose column i is filled with
    the identifying value ids[i] (recoverable from reward[0, i])."""
    ids = np.asarray(ids, np.float32)
    b = len(ids)
    rng = np.random.default_rng(seed)
    return {
        "obs": np.broadcast_to(
            ids[None, :, None, None, None], (t + 1, b) + OBS
        ).astype(np.float32).copy(),
        "action": rng.integers(0, num_actions, (t, b)).astype(np.int32),
        "behavior_logits": rng.normal(0, 1, (t, b, num_actions)
                                      ).astype(np.float32),
        "reward": np.broadcast_to(ids[None, :], (t, b)).astype(
            np.float32).copy(),
        "done": np.zeros((t, b), bool),
    }


def contents(buf):
    """The identifying values currently stored (via the reward channel)."""
    live = np.flatnonzero(buf._live)
    return sorted(buf._arrays["reward"][i][0] for i in live)


def _agent():
    env = catch.make()
    init_fn, apply_fn = minatar_net(env.obs_shape, env.num_actions)
    params, _ = init_agent(init_fn, jax.random.PRNGKey(0))
    return env, apply_fn, params


# -- eviction order ----------------------------------------------------------

@pytest.mark.parametrize("kind", ["uniform", "attentive"])
def test_fifo_eviction_evicts_oldest(kind):
    buf = make_buffer(kind, 4)
    buf.insert(make_rollout([0, 1, 2]))
    buf.insert(make_rollout([3, 4, 5]))       # capacity 4: evicts 0 and 1
    assert len(buf) == 4
    assert contents(buf) == [2, 3, 4, 5]
    assert buf.evicted == 2


def test_elite_eviction_evicts_lowest_priority_first():
    buf = EliteReplay(4)
    buf.insert(make_rollout([0, 1, 2, 3]),
               priorities=np.array([5.0, 1.0, 4.0, 3.0]))
    buf.insert(make_rollout([9]), priorities=np.array([2.0]))
    assert contents(buf) == [0, 2, 3, 9]      # prio-1.0 rollout (id 1) died
    buf.insert(make_rollout([8]), priorities=np.array([6.0]))
    assert contents(buf) == [0, 2, 3, 8]      # next lowest was id 9 (2.0)


def test_optimistic_default_priority_for_unscored_inserts():
    buf = EliteReplay(8)
    buf.insert(make_rollout([0, 1]), priorities=np.array([7.0, 2.0]))
    buf.insert(make_rollout([2]))             # unscored -> current max (7.0)
    live = np.flatnonzero(buf._live)
    assert buf._prio[live].max() == buf._prio[live[-1]] == 7.0


# -- priority feedback --------------------------------------------------------

def test_priority_update_ignores_evicted_slots():
    buf = EliteReplay(2)
    ids = buf.insert(make_rollout([0, 1]))
    _, sampled = buf.sample(2, np.random.default_rng(0))
    buf.insert(make_rollout([2, 3]), priorities=np.array([9.0, 9.0]))
    # ids were fully evicted; stale update must not resurrect them
    buf.update_priorities(ids, np.array([100.0, 100.0]))
    live = np.flatnonzero(buf._live)
    assert (buf._prio[live] == 9.0).all()
    del sampled


def test_elite_priority_updates_after_learner_step():
    """The full feedback loop: Runtime -> train-step 'priority' metric ->
    ReplaySource.on_learner_metrics -> buffer priorities move off the
    optimistic default."""
    env, apply_fn, params = _agent()
    tc = small_train(unroll_length=T, batch_size=B, total_steps=10,
                     clear_policy_cost=0.01, clear_value_cost=0.005)
    opt = make_optimizer(tc)
    src = DeviceSource.for_env(env, apply_fn, unroll_length=T, batch_size=B,
                               key=jax.random.PRNGKey(1), pipelined=False)
    buf = EliteReplay(16)
    rs = ReplaySource(src, buf, replay_ratio=1.0, seed=0)
    step = jax.jit(learner_lib.make_train_step(apply_fn, opt, tc))
    Runtime(rs, step, params, opt.init(params), total_steps=3,
            log_every=0).run()
    # all slots were recycled by stop(); re-run without stop to inspect
    rs.start(params)
    batch = rs.next_batch(params)
    _, _, metrics = step(params, opt.init(params), jnp.int32(0), batch)
    assert metrics["priority"].shape == (2 * B,)
    before = buf._prio[np.flatnonzero(buf._live)].copy()
    rs.on_learner_metrics(0, metrics)
    after = buf._prio[np.flatnonzero(buf._live)]
    assert not np.array_equal(before, after)
    assert (after[np.isfinite(after)] >= 0).all()


# -- attentive similarity -----------------------------------------------------

def test_attentive_samples_nearest_observations():
    buf = AttentiveReplay(8)
    buf.insert(make_rollout([0.0, 0.0, 0.0]))       # obs ~ 0
    buf.insert(make_rollout([10.0, 10.0, 10.0]))    # obs ~ 10
    near_ten = make_rollout([9.0, 9.0, 9.0])
    sampled, _ = buf.sample(3, np.random.default_rng(0),
                            query=near_ten["obs"])
    assert (sampled["reward"] == 10.0).all()
    near_zero = make_rollout([1.0, 1.0, 1.0])
    sampled, _ = buf.sample(3, np.random.default_rng(0),
                            query=near_zero["obs"])
    assert (sampled["reward"] == 0.0).all()


# -- mixed-batch contract -----------------------------------------------------

@pytest.mark.parametrize("kind", ["uniform", "elite", "attentive"])
def test_replay_source_satisfies_rollout_source_contract(kind):
    env, apply_fn, params = _agent()
    src = DeviceSource.for_env(env, apply_fn, unroll_length=T, batch_size=B,
                               key=jax.random.PRNGKey(2), pipelined=False)
    rs = ReplaySource(src, make_buffer(kind, 16), replay_ratio=1.0, seed=0)
    assert isinstance(rs, RolloutSource)
    assert rs.frames_per_batch == T * B       # fresh env frames only
    try:
        rs.start(params)
        for _ in range(3):
            batch = rs.next_batch(params)
            check_rollout(batch, T, 2 * B)    # 1:1 mix -> 2B columns
            assert batch["is_replay"].shape == (2 * B,)
            assert int(batch["is_replay"].sum()) == B
            assert not bool(batch["is_replay"][:B].any())
    finally:
        rs.stop()


def test_replay_ratio_zero_passes_through_fresh_batches():
    env, apply_fn, params = _agent()
    src = DeviceSource.for_env(env, apply_fn, unroll_length=T, batch_size=B,
                               key=jax.random.PRNGKey(3), pipelined=False)
    rs = ReplaySource(src, make_buffer("uniform", 8), replay_ratio=0.0)
    rs.start(params)
    batch = rs.next_batch(params)
    check_rollout(batch, T, B)
    assert not bool(batch["is_replay"].any())
    assert len(rs.buffer) == B                # still feeds the buffer
    rs.stop()


# -- determinism --------------------------------------------------------------

@pytest.mark.parametrize("kind", ["uniform", "elite", "attentive"])
def test_sampling_deterministic_under_fixed_seed(kind):
    def run():
        buf = make_buffer(kind, 8)
        rng = np.random.default_rng(42)
        out = []
        for i in range(4):
            buf.insert(make_rollout([3 * i, 3 * i + 1, 3 * i + 2], seed=i))
            sampled, ids = buf.sample(
                4, rng, query=make_rollout([3 * i]).get("obs"))
            out.append((tuple(ids), sampled["reward"].copy()))
        return out

    a, b = run(), run()
    for (ids_a, r_a), (ids_b, r_b) in zip(a, b):
        assert ids_a == ids_b
        np.testing.assert_array_equal(r_a, r_b)


# -- CLEAR auxiliary loss -----------------------------------------------------

def test_clear_loss_zero_on_fresh_rows_positive_on_replayed():
    rng = np.random.default_rng(0)
    target = jnp.asarray(rng.normal(0, 1, (T, B, A)), jnp.float32)
    behavior = jnp.asarray(rng.normal(0, 1, (T, B, A)), jnp.float32)
    values = jnp.asarray(rng.normal(0, 1, (T, B)), jnp.float32)
    behavior_values = jnp.asarray(rng.normal(0, 1, (T, B)), jnp.float32)
    lp = jax.nn.log_softmax(target, -1)
    pc0, vc0 = losses.clear_auxiliary_loss(
        lp, behavior, values, behavior_values, jnp.zeros((B,), bool))
    assert float(pc0) == float(vc0) == 0.0
    pc, vc = losses.clear_auxiliary_loss(
        lp, behavior, values, behavior_values, jnp.ones((B,), bool))
    assert float(pc) > 0 and float(vc) > 0
    # mu == pi -> policy cloning vanishes even on replayed rows
    pc_same, _ = losses.clear_auxiliary_loss(
        lp, target, values, behavior_values, jnp.ones((B,), bool))
    assert float(pc_same) == pytest.approx(0.0, abs=1e-5)
    # no recorded behavior values -> value cloning disabled
    _, vc_none = losses.clear_auxiliary_loss(
        lp, behavior, values, None, jnp.ones((B,), bool))
    assert float(vc_none) == 0.0
    # value cloning is anchored on the RECORDED values, not V-trace targets
    _, vc_same = losses.clear_auxiliary_loss(
        lp, behavior, values, values, jnp.ones((B,), bool))
    assert float(vc_same) == pytest.approx(0.0, abs=1e-6)


def test_value_fn_records_behavior_values_through_replay_source():
    env, apply_fn, params = _agent()
    tc = small_train(unroll_length=T, batch_size=B, total_steps=10,
                     clear_policy_cost=0.01, clear_value_cost=0.005)
    opt = make_optimizer(tc)
    src = DeviceSource.for_env(env, apply_fn, unroll_length=T, batch_size=B,
                               key=jax.random.PRNGKey(6), pipelined=False)
    rs = ReplaySource(src, make_buffer("uniform", 16), replay_ratio=1.0,
                      value_fn=jax.jit(
                          lambda p, obs: apply_fn(p, obs).baseline))
    step = jax.jit(learner_lib.make_train_step(apply_fn, opt, tc))
    rs.start(params)
    try:
        batch = rs.next_batch(params)
        assert batch["behavior_value"].shape == (T, 2 * B)
        check_rollout(batch, T, 2 * B)
        _, _, m = step(params, opt.init(params), jnp.int32(0), batch)
        assert bool(jnp.isfinite(m["clear_value_loss"]))
        assert bool(jnp.isfinite(m["clear_policy_loss"]))
    finally:
        rs.stop()


def test_replayed_rows_predate_current_step():
    """Sampling happens before insertion: after warmup, every replayed
    column must come from an earlier step (no self-replay bias — the
    attentive strategy would otherwise always pick the just-inserted
    near-identical columns)."""
    env, apply_fn, params = _agent()
    src = DeviceSource.for_env(env, apply_fn, unroll_length=T, batch_size=B,
                               key=jax.random.PRNGKey(7), pipelined=False)
    rs = ReplaySource(src, make_buffer("attentive", 32), replay_ratio=1.0)
    rs.start(params)
    try:
        rs.next_batch(params)              # warmup: samples itself
        for _ in range(3):
            rs.next_batch(params)
            fresh_ids = set(rs._last_ids[:B])
            replay_ids = set(rs._last_ids[B:])
            assert not (fresh_ids & replay_ids)
    finally:
        rs.stop()
    assert rs.stats()["replay_hit_rate"] == pytest.approx(3 * B / (4 * B))


# -- slot-leak regressions ----------------------------------------------------

def test_rollout_buffers_get_batch_returns_indices_on_timeout():
    """Learner dies mid-batch: the already-dequeued indices must come back
    to the free list, or back-pressure deadlocks the actors (regression)."""
    specs = {"reward": ((T,), np.float32)}
    rb = RolloutBuffers(specs, num_buffers=4)
    i = rb.acquire()
    rb.write(i, {"reward": np.ones(T, np.float32)})
    rb.commit(i)                               # only 1 full, need 2
    with pytest.raises(queue.Empty):
        rb.get_batch(batch_size=2, timeout=0.05)
    q = rb.qsizes()
    assert q["free"] + q["full"] == 4          # nothing leaked
    assert q["free"] == 4                      # and it is reusable


def test_replay_insert_returns_slot_on_malformed_rollout():
    buf = UniformReplay(4)
    buf.insert(make_rollout([0, 1]))
    bad = make_rollout([2])
    bad["obs"] = bad["obs"][:, :, :1]          # wrong feature shape
    with pytest.raises(Exception):
        buf.insert(bad)
    assert len(buf) == 2
    assert len(buf._free) + len(buf) == buf.capacity
    buf.insert(make_rollout([3, 4]))           # buffer still fully usable
    assert len(buf) == 4


def test_replay_source_stop_recycles_all_slots():
    env, apply_fn, params = _agent()
    src = DeviceSource.for_env(env, apply_fn, unroll_length=T, batch_size=B,
                               key=jax.random.PRNGKey(4), pipelined=False)
    buf = make_buffer("uniform", 16)
    rs = ReplaySource(src, buf, replay_ratio=1.0)
    rs.start(params)
    rs.next_batch(params)
    assert len(buf) == B
    rs.stop()
    assert len(buf) == 0
    assert len(buf._free) == buf.capacity


def test_replay_source_stop_recycles_even_if_inner_stop_dies():
    class DyingSource:
        frames_per_batch = T * B

        def start(self, params):
            pass

        def next_batch(self, params):
            return make_rollout([0, 1, 2])

        def stop(self):
            raise RuntimeError("learner died mid-batch")

    buf = make_buffer("uniform", 8)
    rs = ReplaySource(DyingSource(), buf, replay_ratio=1.0)
    rs.start(None)
    rs.next_batch(None)
    assert len(buf) == 3
    with pytest.raises(RuntimeError):
        rs.stop()
    assert len(buf) == 0                       # slots recycled regardless
    assert len(buf._free) == buf.capacity


def test_buffer_protocol():
    for kind in ("uniform", "elite", "attentive"):
        assert isinstance(make_buffer(kind, 4), ReplayBuffer)
    with pytest.raises(ValueError):
        make_buffer("nope", 4)


# -- mixed-batch schema drift (satellite: silent key drop) --------------------


class _SchemaShiftSource:
    """Emits the canonical keys, then grows an extra key on later batches
    — the fresh-only-key case the mixed batch used to silently drop."""

    frames_per_batch = T * B

    def __init__(self):
        self.calls = 0

    def start(self, params):
        pass

    def next_batch(self, params):
        r = make_rollout([0.0, 1.0, 2.0])
        if self.calls:
            r["aux"] = np.zeros((T, 3), np.float32)
        self.calls += 1
        return r

    def stop(self):
        pass


def test_mixed_batch_fails_loudly_on_fresh_only_keys():
    """A key present in the fresh rollout but absent from the sampled
    replay columns must not silently vanish from the emitted batch."""
    rs = ReplaySource(_SchemaShiftSource(), make_buffer("uniform", 8),
                      replay_ratio=1.0)
    rs.start(None)
    rs.next_batch(None)                     # schema fixed without "aux"
    with pytest.raises(KeyError, match="fresh-only keys \\['aux'\\]"):
        rs.next_batch(None)


# -- priority feedback shape drift (satellite: silent discard) ----------------


def test_priority_shape_mismatch_warns_once_and_counts():
    """A misaligned priority vector cannot be routed; it must warn (once)
    and count the drop in stats() instead of silently degrading elite
    replay to uniform."""
    import warnings as warnings_mod
    env, apply_fn, params = _agent()
    src = DeviceSource.for_env(env, apply_fn, unroll_length=T, batch_size=B,
                               key=jax.random.PRNGKey(8), pipelined=False)
    rs = ReplaySource(src, make_buffer("elite", 16), replay_ratio=1.0)
    rs.start(params)
    try:
        rs.next_batch(params)
        good = np.ones(2 * B)
        with warnings_mod.catch_warnings(record=True) as caught:
            warnings_mod.simplefilter("always")
            rs.on_learner_metrics(0, {"priority": np.ones(3)})
            rs.on_learner_metrics(1, {"priority": np.ones(2 * B + 1)})
            rs.on_learner_metrics(2, {"priority": good})
        assert len(caught) == 1                       # warn once, not spam
        assert "degrading to uniform" in str(caught[0].message)
        assert rs.stats()["replay_priority_drops"] == 2.0
    finally:
        rs.stop()


# -- sharded replay (per-device-sliced composition) ---------------------------


def test_sharded_replay_mesh1_bit_identical_to_uniform():
    """At mesh size 1 the per-device-sliced buffer must reproduce the
    unsharded composition exactly: same emitted batches, same slot
    tickets, same priority routing."""
    from repro.core.replay import ShardedReplay
    from repro.launch.mesh import make_data_mesh
    env, apply_fn, params = _agent()
    mesh = make_data_mesh(1)

    def make(buffer):
        src = DeviceSource.for_env(env, apply_fn, unroll_length=T,
                                   batch_size=B,
                                   key=jax.random.PRNGKey(9),
                                   pipelined=False)
        return ReplaySource(src, buffer, replay_ratio=1.0, seed=4)

    plain = make(make_buffer("uniform", 12))
    sharded = make(ShardedReplay("uniform", 12, mesh))
    plain.start(params)
    sharded.start(params)
    try:
        for i in range(4):
            a, b = plain.next_batch(params), sharded.next_batch(params)
            assert sorted(a) == sorted(b)
            for key in a:
                np.testing.assert_array_equal(np.asarray(a[key]),
                                              np.asarray(b[key]),
                                              err_msg=key)
            assert [t for (_, t) in sharded._last_ids] == plain._last_ids
            prio = np.arange(2 * B, dtype=np.float64) + i
            plain.on_learner_metrics(i, {"priority": prio})
            sharded.on_learner_metrics(i, {"priority": prio})
            np.testing.assert_array_equal(
                sharded.buffer._parts[0]._prio, plain.buffer._prio)
    finally:
        plain.stop()
        sharded.stop()


def test_sharded_replay_stats_aggregate_partitions():
    from repro.core.replay import ShardedReplay
    from repro.launch.mesh import make_data_mesh
    buf = ShardedReplay("uniform", 8, make_data_mesh(1))
    assert buf.capacity == 8 and len(buf) == 0
    ids = buf.insert(make_rollout([1.0, 2.0, 3.0]))
    assert all(isinstance(i, tuple) and i[0] == 0 for i in ids)
    _, sampled_ids = buf.sample(2, np.random.default_rng(0))
    buf.update_priorities(sampled_ids, np.array([5.0, 6.0]))
    s = buf.stats()
    assert s["occupancy"] == 3 / 8 and s["inserted"] == 3.0
    assert s["sampled"] == 2.0
    buf.clear()
    assert len(buf) == 0
