"""Static analyzer contract tests: every known-bad fixture must be
flagged (out-of-bounds index map, over-budget VMEM, __eq__/__hash__
retrace hazard, dead donation, stale-mesh sharding axis, unlocked
cross-thread write, leaked thread, hot-path host sync), waivers
suppress findings, and the real codebase passes clean."""

import functools
import textwrap

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.common import Finding, apply_waivers
from repro.analysis.concurrency_lint import lint_file, lint_tree
from repro.analysis.kernel_audit import (KernelLaunch, audit_kernels,
                                         audit_launch, capture_launches)
from repro.analysis.trace_audit import (TraceEntry, audit_entry,
                                        audit_static_key, audit_traces)

_SDS = jax.ShapeDtypeStruct


def _rules(findings):
    return {f.rule for f in findings}


def _audit_pallas(fn, *args):
    records = []
    with capture_launches(records, "fixture"):
        jax.eval_shape(fn, *args)
    assert len(records) == 1
    return audit_launch(records[0])


# ---------------------------------------------------------------------------
# kernel_audit fixtures
# ---------------------------------------------------------------------------

def test_kernel_audit_flags_oob_index_map():
    """Index map walks one block past the end of the operand."""

    def bad(x):
        return pl.pallas_call(
            lambda x_ref, o_ref: None,
            grid=(4,),
            in_specs=[pl.BlockSpec((32, 128), lambda i: (i + 1, 0))],
            out_specs=pl.BlockSpec((32, 128), lambda i: (i, 0)),
            out_shape=_SDS((128, 128), jnp.float32),
        )(x)

    findings, table = _audit_pallas(bad, _SDS((128, 128), jnp.float32))
    assert "kernel-index-map-oob" in _rules(findings)
    assert not table["ok"]


def test_kernel_audit_flags_vmem_over_budget():
    """One (2048, 4096) fp32 block is 32 MiB — double-buffered in+out
    blows the 16 MiB budget many times over."""

    def fat(x):
        return pl.pallas_call(
            lambda x_ref, o_ref: None,
            grid=(1,),
            in_specs=[pl.BlockSpec((2048, 4096), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((2048, 4096), lambda i: (0, 0)),
            out_shape=_SDS((2048, 4096), jnp.float32),
        )(x)

    findings, table = _audit_pallas(fat, _SDS((2048, 4096), jnp.float32))
    assert "kernel-vmem-budget" in _rules(findings)
    assert table["vmem_total_bytes"] > 16 * 1024 * 1024


def test_kernel_audit_flags_non_dividing_block():
    launch = KernelLaunch(
        kernel="fixture", grid=(3,),
        in_specs=[pl.BlockSpec((48,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((48,), lambda i: (i,))],
        operands=[_SDS((100,), jnp.float32)],
        out_shapes=[_SDS((144,), jnp.float32)], scratch_shapes=())
    findings, _ = audit_launch(launch)
    assert "kernel-block-divisibility" in _rules(findings)


def test_kernel_audit_real_kernels_clean_and_complete():
    """The shipped kernels pass, and the footprint table covers all four
    kernels for every audited arch."""
    findings, tables = audit_kernels(["qwen3-4b", "zamba2-2.7b"])
    assert findings == []
    for arch in ("qwen3-4b", "zamba2-2.7b"):
        kernels = {t["kernel"] for t in tables if t["arch"] == arch}
        assert kernels == {"flash_attention", "decode_attention",
                           "ssd_chunk", "vtrace"}
    for t in tables:
        assert t["vmem_total_bytes"] <= t["vmem_budget_bytes"]
        assert t["roofline"]["flops"] > 0


# ---------------------------------------------------------------------------
# trace_audit fixtures
# ---------------------------------------------------------------------------

class _IdHashCfg:
    """__eq__ by value but __hash__ by identity: the classic retrace
    storm — every freshly built (but equal) config recompiles."""

    def __init__(self, d):
        self.d = d

    def __eq__(self, other):
        return isinstance(other, _IdHashCfg) and self.d == other.d

    __hash__ = object.__hash__


class _UnhashableCfg:
    def __init__(self, d):
        self.d = d

    def __eq__(self, other):           # defining __eq__ kills __hash__
        return isinstance(other, _UnhashableCfg) and self.d == other.d


def test_static_key_flags_eq_hash_mismatch():
    findings = audit_static_key(lambda: _IdHashCfg(8), "IdHashCfg")
    assert _rules(findings) == {"retrace-hazard"}
    findings = audit_static_key(lambda: _UnhashableCfg(8), "UnhashableCfg")
    assert _rules(findings) == {"retrace-hazard"}
    assert audit_static_key(lambda: (1, 2), "tuple") == []


def test_audit_entry_flags_retrace_from_id_hash_static():
    """The jit-level detector: two traces for fresh-but-equal statics."""

    def fn(x, cfg):
        return x * cfg.d

    entry = TraceEntry(
        name="fixture-retrace", fn=fn,
        make_args=lambda: ((_SDS((4,), jnp.float32),),
                           {"cfg": _IdHashCfg(3)}),
        jit_kwargs={"static_argnames": ("cfg",)})
    findings, summary = audit_entry(entry)
    assert "retrace-hazard" in _rules(findings)
    assert summary["traces"] == 2


def test_audit_entry_flags_dead_donation():
    """Donating a buffer with no (shape, dtype)-matching output."""

    def fn(big, x):
        return x + 1.0

    entry = TraceEntry(
        name="fixture-donation", fn=fn,
        make_args=lambda: ((_SDS((64, 64), jnp.float32),
                            _SDS((4,), jnp.float32)), {}),
        jit_kwargs={"donate_argnums": (0,)})
    findings, _ = audit_entry(entry)
    assert "donation-dead" in _rules(findings)


def test_audit_entry_flags_stale_mesh_axis():
    """A sharding constraint built on a mesh whose axes are not live on
    the entry's declared mesh."""
    live = jax.sharding.AbstractMesh((("data", 2),))
    stale = jax.sharding.AbstractMesh((("model", 2),))
    P = jax.sharding.PartitionSpec

    def fn(x):
        s = jax.sharding.NamedSharding(stale, P("model"))
        return jax.lax.with_sharding_constraint(x, s)

    entry = TraceEntry(
        name="fixture-stale-axis", fn=fn,
        make_args=lambda: ((_SDS((8, 8), jnp.float32),), {}),
        jit_kwargs={}, mesh=live)
    findings, _ = audit_entry(entry)
    assert "sharding-unknown-axis" in _rules(findings)


def test_trace_audit_real_entries_clean():
    findings, summaries = audit_traces(archs=["qwen3-4b"])
    assert findings == []
    by_name = {s["entry"]: s for s in summaries}
    assert any(n.startswith("make_lm_train_step") for n in by_name)
    assert any(n.startswith("_session_step") for n in by_name)
    for s in by_name.values():
        assert s["traces"] == 1, s


# ---------------------------------------------------------------------------
# concurrency_lint fixtures
# ---------------------------------------------------------------------------

def _lint_snippet(tmp_path, source, *, hot=None):
    path = tmp_path / "snippet.py"
    path.write_text(textwrap.dedent(source))
    return lint_file(str(path), hot=hot)


def test_lint_flags_unlocked_cross_thread_write(tmp_path):
    findings = _lint_snippet(tmp_path, """
        import threading

        class Racy:
            def start(self):
                self._t = threading.Thread(target=self._loop)
                self._t.start()

            def _loop(self):
                self.count = 1 + getattr(self, "count", 0)

            def stop(self):
                self._t.join()

            def read(self):
                return self.count
        """)
    assert "thread-shared-write" in _rules(findings)
    assert "thread-no-join" not in _rules(findings)


def test_lint_lock_guard_suppresses_shared_write(tmp_path):
    findings = _lint_snippet(tmp_path, """
        import threading

        class Locked:
            def start(self):
                self._lock = threading.Lock()
                self._t = threading.Thread(target=self._loop)
                self._t.start()

            def _loop(self):
                with self._lock:
                    self.count = 1

            def stop(self):
                self._t.join()

            def read(self):
                with self._lock:
                    return self.count
        """)
    assert "thread-shared-write" not in _rules(findings)


def test_lint_flags_thread_without_join(tmp_path):
    findings = _lint_snippet(tmp_path, """
        import threading

        class Leaky:
            def start(self):
                self._t = threading.Thread(target=lambda: None)
                self._t.start()

            def stop(self):
                pass
        """)
    assert "thread-no-join" in _rules(findings)


def test_lint_flags_host_sync_in_hot_module(tmp_path):
    findings = _lint_snippet(tmp_path, """
        import numpy as np
        import jax

        def hot_loop(x):
            a = x.item()
            b = np.asarray(x)
            c = jax.device_get(x)
            x.block_until_ready()
            return a, b, c
        """, hot=True)
    assert [f.rule for f in findings] == ["host-sync"] * 4


def test_waiver_suppresses_finding(tmp_path):
    findings = _lint_snippet(tmp_path, """
        import numpy as np

        def hot_loop(x):
            return np.asarray(x)  # analysis: ignore[host-sync]
        """, hot=True)
    findings = apply_waivers(findings)
    assert len(findings) == 1 and findings[0].waived
    unrelated = apply_waivers([Finding(
        rule="other-rule", file=str(tmp_path / "snippet.py"), line=5,
        message="x")])
    assert not unrelated[0].waived       # waiver names a different rule


def test_lint_real_tree_clean():
    findings = apply_waivers(lint_tree())
    assert [f for f in findings if not f.waived] == []


# ---------------------------------------------------------------------------
# interpret-fallback stats (kernels/compat.py)
# ---------------------------------------------------------------------------

def test_resolve_interpret_counts_fallbacks():
    from repro.kernels.compat import resolve_interpret
    before = resolve_interpret.stats()
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    after = resolve_interpret.stats()
    assert after["explicit"] == before["explicit"] + 2
    resolve_interpret(None)            # CPU CI: counted, not silent
    if jax.default_backend() == "tpu":
        assert resolve_interpret.stats()["compiled"] == \
            before["compiled"] + 1
    else:
        assert resolve_interpret.stats()["fallbacks"] == \
            before["fallbacks"] + 1


# ---------------------------------------------------------------------------
# batched admission (DecodeSession.prefill_many)
# ---------------------------------------------------------------------------

def test_prefill_many_matches_prefill_into():
    """Batched admit must produce the same per-slot state and first
    tokens as N sequential prefill_into calls with the same inputs —
    and mixed prompt lengths must group into per-bucket dispatches."""
    import numpy as np

    from repro.configs import get_reduced_config
    from repro.core.generate import DecodeSession
    from repro.models import model as model_lib

    cfg = get_reduced_config("xlstm-125m")   # recurrent: exact buckets
    params, _ = model_lib.init(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    prompts = [np.array([3, 5, 7], np.int32), np.array([11], np.int32),
               np.array([2, 4], np.int32)]
    keys = list(jax.random.split(key, 3))

    def run_steps(sess, n=4):
        toks = []
        for _ in range(n):
            toks.append(sess.step()["token"][:3].copy())
        return np.stack(toks)

    a = DecodeSession(params, cfg, max_batch=4, max_len=16)
    first_a = [a.prefill_into(i, prompts[i], key=keys[i],
                              temperature=0.7) for i in range(3)]
    tokens_a = run_steps(a)

    b = DecodeSession(params, cfg, max_batch=4, max_len=16)
    first_b = b.prefill_many([0, 1, 2], prompts, keys=keys,
                             temperature=0.7)
    tokens_b = run_steps(b)

    assert list(b.active[:3]) == [True] * 3 and not b.active[3]
    for fa, fb in zip(first_a, first_b):
        assert fa.keys() == fb.keys()
        for k in fa:
            np.testing.assert_allclose(fa[k], fb[k], rtol=1e-5,
                                       atol=1e-6, err_msg=k)
    np.testing.assert_array_equal(tokens_a, tokens_b)


def test_prefill_many_rejects_bad_slots():
    import numpy as np
    import pytest

    from repro.configs import get_reduced_config
    from repro.core.generate import DecodeSession
    from repro.models import model as model_lib

    cfg = get_reduced_config("xlstm-125m")
    params, _ = model_lib.init(jax.random.PRNGKey(0), cfg)
    sess = DecodeSession(params, cfg, max_batch=2, max_len=8)
    p = [np.array([1], np.int32)] * 2
    keys = list(jax.random.split(jax.random.PRNGKey(0), 2))
    with pytest.raises(ValueError, match="duplicate"):
        sess.prefill_many([0, 0], p, keys=keys)
    sess.prefill_into(1, p[0], key=keys[0])
    with pytest.raises(ValueError, match="occupied"):
        sess.prefill_many([0, 1], p, keys=keys)
