"""Data-parallel sharded learner tests (PR 3).

In-process (run on whatever devices the env has — 1 in the tier-1 suite,
8 in the sharded-cpu CI job):
  * mesh-size-1 is BIT-identical to the pre-change unsharded path (source
    stream, per-step losses, final params);
  * the Pallas V-trace kernel impl matches the scan impl in the
    learner-step metrics to 1e-5;
  * Runtime crash checkpointing, --resume/start_step, DeviceSource stop()
    state reset, windowed FPS.

Multi-device (subprocess under XLA_FLAGS=--xla_force_host_platform_
device_count=8, so it runs everywhere): mesh 1 vs 4 produce equal losses
on the same batches, and ShardedDeviceSource round-trips check_rollout.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec

from conftest import run_forced
from repro.configs.atari_impala import small_train
from repro.core import learner as learner_lib
from repro.core.runtime import Runtime
from repro.core.sources import (DeviceSource, ShardedDeviceSource,
                                check_rollout)
from repro.distributed.sharding import RL_AGENT_RULES, RULE_SETS, spec_for
from repro.envs import catch
from repro.launch.mesh import make_data_mesh
from repro.models.convnet import init_agent, minatar_net
from repro.optim import make_optimizer

T, B = 10, 8


def _agent():
    env = catch.make()
    init_fn, apply_fn = minatar_net(env.obs_shape, env.num_actions)
    params, _ = init_agent(init_fn, jax.random.PRNGKey(0))
    return env, apply_fn, params


def _fixed_batch(env, seed=0, t=T, b=B):
    rng = np.random.default_rng(seed)
    return {
        "obs": jnp.asarray(rng.random((t + 1, b) + env.obs_shape),
                           jnp.float32),
        "action": jnp.asarray(rng.integers(0, env.num_actions, (t, b)),
                              jnp.int32),
        "behavior_logits": jnp.asarray(
            rng.normal(0, 1, (t, b, env.num_actions)), jnp.float32),
        "reward": jnp.asarray(rng.normal(0, 1, (t, b)), jnp.float32),
        "done": jnp.asarray(rng.random((t, b)) > 0.9),
    }


# ---------------------------------------------------------------------------
# rules table


def test_rl_agent_rules_replicate_params_shard_batch():
    assert RULE_SETS["rl_agent"] is RL_AGENT_RULES
    mesh = make_data_mesh(1)
    # every convnet/fc param axis replicated
    for axes in (("conv_h", "conv_w", "conv_in", "conv_out"),
                 ("fc_in", "fc_out")):
        assert spec_for(axes, mesh, RL_AGENT_RULES) == PartitionSpec()
    # activations shard their batch axis over the data axes
    assert spec_for(("act_batch",), mesh, RL_AGENT_RULES) == \
        PartitionSpec("data")


# ---------------------------------------------------------------------------
# mesh-size-1 bit-parity with the pre-change path


def test_sharded_source_mesh1_bit_identical_to_device_source():
    """Same key → the per-device fan-out at N=1 must reproduce the exact
    DeviceSource rollout stream (and obey the canonical contract)."""
    env, apply_fn, params = _agent()
    mesh = make_data_mesh(1)
    a = DeviceSource.for_env(env, apply_fn, unroll_length=T, batch_size=B,
                             key=jax.random.PRNGKey(3), pipelined=True)
    b = ShardedDeviceSource.for_env(env, apply_fn, unroll_length=T,
                                    batch_size=B, key=jax.random.PRNGKey(3),
                                    mesh=mesh, pipelined=True)
    assert b.frames_per_batch == a.frames_per_batch == T * B
    for _ in range(3):
        ra, rb = a.next_batch(params), b.next_batch(params)
        check_rollout(rb, T, B)
        jax.tree.map(lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)), ra, rb)


def test_sharded_training_mesh1_bit_identical():
    """4 learner steps through the sharded path at mesh size 1 == the
    pre-change unsharded path, bit for bit (losses and final params)."""
    env, apply_fn, params0 = _agent()
    tc = small_train(unroll_length=T, batch_size=B, total_steps=50)
    opt = make_optimizer(tc)

    def run(mesh):
        src_kw = dict(unroll_length=T, batch_size=B,
                      key=jax.random.PRNGKey(1), pipelined=True)
        if mesh is None:
            source = DeviceSource.for_env(env, apply_fn, **src_kw)
            params = params0
        else:
            source = ShardedDeviceSource.for_env(env, apply_fn, mesh=mesh,
                                                 **src_kw)
            params = jax.device_put(
                params0, NamedSharding(mesh, PartitionSpec()))
        step = jax.jit(learner_lib.make_train_step(apply_fn, opt, tc,
                                                   mesh=mesh))
        opt_state = opt.init(params)
        losses = []
        for s in range(4):
            batch = source.next_batch(params)
            params, opt_state, m = step(params, opt_state, jnp.int32(s),
                                        batch)
            losses.append(float(m["loss"]))
        source.stop()
        return losses, params

    losses_a, params_a = run(None)
    losses_b, params_b = run(make_data_mesh(1))
    assert losses_a == losses_b
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), params_a, params_b)


# ---------------------------------------------------------------------------
# V-trace kernel impl on the learner hot path


def test_vtrace_kernel_impl_matches_scan_in_learner():
    """--vtrace-impl kernel: learner-step metrics match the scan impl to
    1e-5 (the kernel runs interpret-mode on CPU)."""
    env, apply_fn, params = _agent()
    tc = small_train(unroll_length=T, batch_size=B)
    opt = make_optimizer(tc)
    batch = _fixed_batch(env)
    out = {}
    for impl in ("scan", "kernel"):
        step = jax.jit(learner_lib.make_train_step(apply_fn, opt, tc,
                                                   vtrace_impl=impl))
        p, _, m = step(params, opt.init(params), jnp.int32(0), batch)
        out[impl] = (m, p)
    for k in ("loss", "pg_loss", "baseline_loss", "entropy_loss",
              "vs_mean", "rho_mean"):
        np.testing.assert_allclose(float(out["scan"][0][k]),
                                   float(out["kernel"][0][k]),
                                   rtol=1e-5, atol=1e-5, err_msg=k)
    jax.tree.map(lambda x, y: np.testing.assert_allclose(
        np.asarray(x), np.asarray(y), rtol=1e-5, atol=1e-6),
        out["scan"][1], out["kernel"][1])


def test_vtrace_impl_rejects_unknown():
    from repro.core import losses
    with pytest.raises(ValueError):
        losses._vtrace_fn("fancy")


def test_vtrace_kernel_impl_matches_scan_logprob_path():
    """The LM-RL loss path (--mode lm-rl --vtrace-impl kernel) hits the
    kernel too: impala_loss_from_logprobs scan vs kernel to 1e-5."""
    from repro.core import losses
    rng = np.random.default_rng(0)
    args = dict(
        target_logprobs=jnp.asarray(rng.normal(-1.5, 0.3, (T, B)),
                                    jnp.float32),
        target_entropy=jnp.asarray(rng.random((T, B)), jnp.float32),
        behavior_logprobs=jnp.asarray(rng.normal(-1.5, 0.3, (T, B)),
                                      jnp.float32),
        rewards=jnp.asarray(rng.normal(0, 1, (T, B)), jnp.float32),
        discounts=jnp.asarray(rng.random((T, B)), jnp.float32),
        values=jnp.asarray(rng.normal(0, 1, (T, B)), jnp.float32),
        bootstrap_value=jnp.asarray(rng.normal(0, 1, (B,)), jnp.float32))
    a = losses.impala_loss_from_logprobs(**args, vtrace_impl="scan")
    b = losses.impala_loss_from_logprobs(**args, vtrace_impl="kernel")
    for k in ("total", "pg_loss", "baseline_loss", "vs_mean"):
        np.testing.assert_allclose(float(getattr(a, k)),
                                   float(getattr(b, k)),
                                   rtol=1e-5, atol=1e-5, err_msg=k)


# ---------------------------------------------------------------------------
# mesh 1 vs N parity + sharded contract (8 forced host devices, hermetic
# subprocess — conftest.run_forced — so it passes in the single-device
# tier-1 env too)

_PARITY_SCRIPT = r"""
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.atari_impala import small_train
from repro.core import learner as L
from repro.core.sources import ShardedDeviceSource, check_rollout
from repro.distributed.sharding import RL_AGENT_RULES
from repro.envs import catch
from repro.launch.mesh import make_data_mesh
from repro.models.convnet import init_agent, minatar_net
from repro.optim import make_optimizer

T, B = 10, 8
env = catch.make()
tc = small_train(unroll_length=T, batch_size=B, total_steps=50)
init_fn, apply_fn = minatar_net(env.obs_shape, env.num_actions)
params0, _ = init_agent(init_fn, jax.random.PRNGKey(0))
opt = make_optimizer(tc)

rng = np.random.default_rng(0)
batches = []
for _ in range(3):
    batches.append({
        "obs": rng.random((T + 1, B) + env.obs_shape).astype(np.float32),
        "action": rng.integers(0, env.num_actions, (T, B)).astype(np.int32),
        "behavior_logits": rng.normal(
            0, 1, (T, B, env.num_actions)).astype(np.float32),
        "reward": rng.normal(0, 1, (T, B)).astype(np.float32),
        "done": rng.random((T, B)) > 0.9,
    })

def losses_on(n):
    mesh = make_data_mesh(n)
    step = jax.jit(L.make_train_step(apply_fn, opt, tc, mesh=mesh,
                                     rules=RL_AGENT_RULES))
    params = jax.device_put(params0, NamedSharding(mesh, PartitionSpec()))
    opt_state = opt.init(params)
    sharding = lambda nd: NamedSharding(  # noqa: E731
        mesh, PartitionSpec(*([None, "data"] + [None] * (nd - 2))))
    out = []
    for s, b in enumerate(batches):
        b = {k: jax.device_put(jnp.asarray(v), sharding(v.ndim))
             for k, v in b.items()}
        params, opt_state, m = step(params, opt_state, jnp.int32(s), b)
        out.append(float(m["loss"]))
    return out

l1, l4 = losses_on(1), losses_on(4)
print("mesh1", l1)
print("mesh4", l4)
np.testing.assert_allclose(l1, l4, rtol=1e-5, atol=1e-6)

# the sharded source fans 4 per-device streams into one global batch that
# round-trips the canonical contract, laid out over the mesh
mesh = make_data_mesh(4)
src = ShardedDeviceSource.for_env(env, apply_fn, unroll_length=T,
                                  batch_size=4 * B,
                                  key=jax.random.PRNGKey(1), mesh=mesh)
rollout = src.next_batch(params0)
check_rollout(rollout, T, 4 * B)
assert len(rollout["obs"].sharding.device_set) == 4
assert all(len(s.data.devices()) == 1
           for s in rollout["obs"].addressable_shards)
src.stop()

# sharded replay composes over the sharded source: per-device-sliced
# storage, mixed batch stays globally sharded (one shard per device, so
# no host concat / resharding entered the hot path), per-device
# interleaved is_replay mask, priorities route through (device, ticket)
from repro.core.sources import ReplaySource
from repro.core.replay import ShardedReplay
src = ShardedDeviceSource.for_env(env, apply_fn, unroll_length=T,
                                  batch_size=4 * B,
                                  key=jax.random.PRNGKey(2), mesh=mesh)
rs = ReplaySource(src, ShardedReplay("elite", 32, mesh), replay_ratio=1.0)
rs.start(params0)
for i in range(3):
    mixed = rs.next_batch(params0)
    check_rollout(mixed, T, 8 * B)
    assert len(mixed["obs"].sharding.device_set) == 4
    assert all(len(s.data.devices()) == 1
               for s in mixed["obs"].addressable_shards)
    mask = np.asarray(mixed["is_replay"])
    np.testing.assert_array_equal(
        mask, np.tile([False] * B + [True] * B, 4))
    rs.on_learner_metrics(i, {"priority": np.arange(8 * B,
                                                    dtype=np.float64)})
parts = rs.buffer._parts
assert all(len(p) > 0 for p in parts)
assert any((p._prio[p._live] != 1.0).any() for p in parts)
rs.stop()

# divisibility is enforced loudly
try:
    ShardedReplay("uniform", 30, mesh)
except ValueError as e:
    assert "not divisible" in str(e)
else:
    raise AssertionError("capacity 30 over 4 devices should fail")

# the host actor loop feeds the sharded learner: its stacked batch is
# split over the mesh data axis
from repro.core.sources import HostLoopSource
host = HostLoopSource(env, apply_fn, num_actors=4, unroll_length=T,
                      batch_size=4 * B, mesh=mesh)
try:
    host.start(params0)
    hr = host.next_batch(params0)
    check_rollout(hr, T, 4 * B)
    assert len(hr["obs"].sharding.device_set) == 4
finally:
    host.stop()
print("PARITY OK")
"""


def test_sharded_parity_mesh_1_vs_4_subprocess():
    proc = run_forced(script=_PARITY_SCRIPT, devices=8)
    assert "PARITY OK" in proc.stdout


# ---------------------------------------------------------------------------
# satellite fixes


class _CrashingSource:
    """Canonical source that blows up on the k-th batch (actor stall)."""

    def __init__(self, inner, crash_at):
        self.inner = inner
        self.crash_at = crash_at
        self.frames_per_batch = inner.frames_per_batch
        self.calls = 0

    def start(self, params):
        self.inner.start(params)

    def next_batch(self, params):
        if self.calls == self.crash_at:
            raise TimeoutError("actor stalled")
        self.calls += 1
        return self.inner.next_batch(params)

    def stop(self):
        self.inner.stop()


def test_runtime_crash_checkpoint_saves_progress(tmp_path):
    """A mid-training exception persists the last completed state (and
    re-raises); a second Runtime resumes from it at the saved step."""
    from repro import checkpoint as ckpt_lib
    env, apply_fn, params = _agent()
    tc = small_train(unroll_length=T, batch_size=B, total_steps=50)
    opt = make_optimizer(tc)
    src = _CrashingSource(
        DeviceSource.for_env(env, apply_fn, unroll_length=T, batch_size=B,
                             key=jax.random.PRNGKey(5), pipelined=False),
        crash_at=3)
    step = jax.jit(learner_lib.make_train_step(apply_fn, opt, tc))
    rt = Runtime(src, step, params, opt.init(params), total_steps=10,
                 log_every=0, checkpoint_dir=str(tmp_path),
                 print_fn=lambda s: None)
    with pytest.raises(TimeoutError):
        rt.run()
    path = ckpt_lib.latest_step_path(str(tmp_path))
    assert path is not None and os.path.basename(path) == "step_3"
    restored, meta = ckpt_lib.restore(
        path, {"params": params, "opt_state": opt.init(params)})
    assert meta["step"] == 3
    # the checkpoint carries the params of the last COMPLETED step
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), restored["params"], rt.params)

    # resume from it: the loop continues at step 3 (LR schedule intact)
    steps_seen = []
    src2 = DeviceSource.for_env(env, apply_fn, unroll_length=T,
                                batch_size=B, key=jax.random.PRNGKey(6))
    rt2 = Runtime(src2, step, restored["params"], restored["opt_state"],
                  total_steps=5, start_step=meta["step"], log_every=0,
                  on_metrics=lambda s, m: steps_seen.append(s),
                  print_fn=lambda s: None)
    rt2.run()
    assert steps_seen == [3, 4]


def test_runtime_crash_after_update_saves_next_step(tmp_path):
    """A failure AFTER the params update (e.g. in a metrics hook) must
    checkpoint step+1 — resuming must not re-apply the completed update."""
    from repro import checkpoint as ckpt_lib
    env, apply_fn, params = _agent()
    tc = small_train(unroll_length=T, batch_size=B, total_steps=50)
    opt = make_optimizer(tc)
    src = DeviceSource.for_env(env, apply_fn, unroll_length=T, batch_size=B,
                               key=jax.random.PRNGKey(5), pipelined=False)
    step = jax.jit(learner_lib.make_train_step(apply_fn, opt, tc))

    def boom(s, m):
        if s == 2:
            raise RuntimeError("metrics sink died")

    rt = Runtime(src, step, params, opt.init(params), total_steps=10,
                 log_every=0, checkpoint_dir=str(tmp_path), on_metrics=boom,
                 print_fn=lambda s: None)
    with pytest.raises(RuntimeError):
        rt.run()
    # update 2 IS in rt.params, so the checkpoint must say "run step 3 next"
    path = ckpt_lib.latest_step_path(str(tmp_path))
    assert os.path.basename(path) == "step_3"
    _, meta = ckpt_lib.restore(
        path, {"params": params, "opt_state": opt.init(params)})
    assert meta["step"] == 3


def test_runtime_no_crash_checkpoint_without_dir(tmp_path):
    env, apply_fn, params = _agent()
    tc = small_train(unroll_length=T, batch_size=B)
    opt = make_optimizer(tc)
    src = _CrashingSource(
        DeviceSource.for_env(env, apply_fn, unroll_length=T, batch_size=B,
                             key=jax.random.PRNGKey(5), pipelined=False),
        crash_at=0)
    step = jax.jit(learner_lib.make_train_step(apply_fn, opt, tc))
    rt = Runtime(src, step, params, opt.init(params), total_steps=4,
                 log_every=0, print_fn=lambda s: None)
    with pytest.raises(TimeoutError):
        rt.run()
    assert list(tmp_path.iterdir()) == []


def test_train_cli_resume_continues_from_saved_step(tmp_path, capsys):
    """Killed-and-resumed via the CLI: the second run restores
    {params, opt_state, step} and starts at the saved step, not 0."""
    from repro.launch import train as train_cli
    d = str(tmp_path)
    args = ["--mode", "rl-agent", "--env", "catch", "--batch", "8"]
    train_cli.main(args + ["--steps", "3", "--checkpoint-dir", d])
    assert os.path.exists(os.path.join(tmp_path, "step_3", "manifest.json"))
    capsys.readouterr()
    train_cli.main(args + ["--steps", "5", "--checkpoint-dir", d,
                           "--resume"])
    out = capsys.readouterr().out
    assert "resumed" in out and "at step 3" in out
    # the continued loop logs steps 3.. only — the schedule did not restart
    assert "step     3" in out and "step     0" not in out
    assert os.path.exists(os.path.join(tmp_path, "step_5", "manifest.json"))


def test_runtime_resume_past_end_writes_no_relabeled_checkpoint(tmp_path):
    """--resume --steps N with a saved step >= N runs nothing and must NOT
    relabel the restored state with a smaller step number."""
    env, apply_fn, params = _agent()
    tc = small_train(unroll_length=T, batch_size=B)
    opt = make_optimizer(tc)
    src = DeviceSource.for_env(env, apply_fn, unroll_length=T, batch_size=B,
                               key=jax.random.PRNGKey(5))
    step = jax.jit(learner_lib.make_train_step(apply_fn, opt, tc))
    rt = Runtime(src, step, params, opt.init(params), total_steps=3,
                 start_step=5, log_every=0, checkpoint_dir=str(tmp_path),
                 print_fn=lambda s: None)
    rt.run()
    assert list(tmp_path.iterdir()) == []


def test_device_source_stop_resets_dispatch_state():
    """Stale-restart fix: after stop(), a restarted source with
    param_sync_every > 1 must act with the NEW params, not last run's."""
    env, apply_fn, params = _agent()
    newer = jax.tree.map(lambda x: x + 1.0, params)
    for make in (
        lambda: DeviceSource.for_env(
            env, apply_fn, unroll_length=T, batch_size=B,
            key=jax.random.PRNGKey(4), pipelined=False,
            param_sync_every=2),
        lambda: ShardedDeviceSource.for_env(
            env, apply_fn, unroll_length=T, batch_size=B,
            key=jax.random.PRNGKey(4), mesh=make_data_mesh(1),
            pipelined=False, param_sync_every=2),
    ):
        src = make()
        src.start(params)
        src.next_batch(params)     # dispatch 0: behavior <- params
        src.stop()
        assert src._behavior_params is None and src._dispatches == 0
        src.start(newer)
        src.next_batch(newer)      # dispatch 0 of the NEW run: resync
        held = src._behavior_params
        held_leaf = jax.tree.leaves(
            held[0] if isinstance(held, list) else held)[0]
        np.testing.assert_array_equal(np.asarray(held_leaf),
                                      np.asarray(jax.tree.leaves(newer)[0]))


def test_windowed_fps_reflects_recent_rate(monkeypatch):
    """The fps column is windowed (since the previous log line); the
    lifetime average moves to fps_avg — a late slowdown must show up."""
    import repro.core.runtime as runtime_mod

    class _Src:
        frames_per_batch = 100

        def start(self, p):
            pass

        def next_batch(self, p):
            return None

        def stop(self):
            pass

    rt = Runtime(_Src(), lambda p, o, s, b: (p, o, {}), None, None,
                 total_steps=10, log_every=1)
    lines = []
    rt.print_fn = lines.append
    rt.metrics = {}
    clock = iter([0.0, 1.0, 2.0])  # t0, first _log, second _log
    monkeypatch.setattr(runtime_mod.time, "time", lambda: next(clock))
    t0 = runtime_mod.time.time()
    rt._win_t, rt._win_frames = t0, 0
    rt.frames = 1000
    rt._log(0, t0)                 # 1000 frames in 1s
    rt.frames = 1100
    rt._log(1, t0)                 # only 100 frames in the last second
    assert "fps=1000" in lines[0] and "fps_avg=1000" in lines[0]
    assert "fps=100 " in lines[1] + " "
    assert "fps_avg=550" in lines[1]
