"""MoE routing invariants and dispatch correctness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import moe
from repro.models.common import split_params


def _cfg(**over):
    return dataclasses.replace(get_reduced_config("mixtral-8x7b"), **over)


def _params(cfg, key=0):
    return split_params(moe.moe_init(jax.random.PRNGKey(key), cfg))[0]


def test_output_matches_dense_expert_computation():
    """With ample capacity, the dispatch/combine einsums must equal the
    naive per-token top-k expert mixture."""
    cfg = _cfg(capacity_factor=8.0)
    params = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    out, aux = moe.moe_apply(params, x, cfg)
    assert float(aux.dropped_frac) == 0.0

    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    topp, topi = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    topp = topp / topp.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xt)
    for e in range(cfg.num_experts):
        h = xt @ params["wi"][e]
        g = xt @ params["wg"][e]
        eo = (h * jax.nn.silu(g)) @ params["wo"][e]
        w = jnp.where(topi == e, topp, 0.0).sum(-1)
        ref = ref + w[:, None] * eo
    np.testing.assert_allclose(out.reshape(-1, cfg.d_model), ref,
                               rtol=3e-3, atol=3e-3)


def test_capacity_drops_tokens():
    cfg = _cfg(capacity_factor=0.25)
    params = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 64, cfg.d_model))
    out, aux = moe.moe_apply(params, x, cfg)
    assert float(aux.dropped_frac) > 0.0
    assert bool(jnp.isfinite(out).all())


def test_load_balance_loss_uniform_is_one():
    """With perfectly uniform routing, the Switch load-balance loss -> 1."""
    cfg = _cfg(num_experts=4, num_experts_per_tok=1)
    params = _params(cfg)
    # zero router weights => uniform probs; top-1 tie-broken by index, so
    # ce is deterministic; lb = E * sum(me*ce)/k = 4 * (0.25*1)/1 ... only
    # me is uniform. Check lb >= 1 (its minimum, attained at balance).
    params = dict(params, router=jnp.zeros_like(params["router"]))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model))
    _, aux = moe.moe_apply(params, x, cfg)
    assert float(aux.load_balance) >= 1.0 - 1e-5


# Seeded sweep standing in for the former hypothesis property test, so the
# suite runs on a bare install (hypothesis is an optional extra).
@pytest.mark.parametrize("seed,b", [(0, 1), (7, 2), (101, 4), (577, 2),
                                    (1000, 1)])
def test_router_gradients_finite(seed, b):
    cfg = _cfg()
    params = _params(cfg, key=seed % 7)
    x = jax.random.normal(jax.random.PRNGKey(seed), (b, 32, cfg.d_model))

    def loss(p):
        out, aux = moe.moe_apply(p, x, cfg)
        return jnp.sum(jnp.square(out)) + aux.load_balance + aux.z_loss

    g = jax.grad(loss)(params)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.isfinite(leaf).all())
