"""Sharded manifest checkpoints (the multi-host checkpoint fix): per-host
shard files + manifest completion marker, atomic/async writes, and ELASTIC
resume.

* manifest layout: ``step_N/`` holds per-process ``shard-*.npz`` + sidecars
  and a ``manifest.json`` completion marker written last;
* a save killed mid-write (no manifest) is invisible to
  ``latest_step_path`` — torn writes never shadow the last good step;
* ``restore`` validates the manifest against the template tree UP FRONT,
  naming mismatched keys; the train CLI validates the recorded run config
  before touching any shard;
* sharded (2,2) save -> same-mesh restore is bitwise; -> (4,1) restore
  (a mesh the save never saw) is bitwise too, straight onto devices via
  ``make_array_from_single_device_arrays``;
* a (2,2)-mesh lm run checkpointed mid-training and resumed on (4,1)
  matches the uninterrupted run's per-step losses to 1e-5;
* a REAL 2-process fleet (jax.distributed over loopback, gloo CPU
  collectives) checkpoints cooperatively, survives SIGKILL of every
  process mid-run, and ``--resume``s to bitwise-identical final params;
* the background writer surfaces failures on flush and a failed crash
  checkpoint re-raises the ORIGINAL training error;
* legacy single-file ``step_N.npz`` checkpoints still restore.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import prune_after, run_coordinated, run_forced
from repro import checkpoint as ckpt_lib
from repro.checkpoint import AsyncCheckpointWriter, CheckpointWriteError

# ---------------------------------------------------------------------------
# manifest format + completion-marker semantics (single process)
# ---------------------------------------------------------------------------


def test_manifest_layout_and_roundtrip(tmp_path):
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "step": jnp.int32(7)}
    path = str(tmp_path / "step_1")
    ckpt_lib.save(path, tree, {"step": 1, "mode": "lm"})

    names = sorted(os.listdir(path))
    assert names == ["manifest.json", "shard-00000.json", "shard-00000.npz"]
    assert not [n for n in names if n.endswith(".tmp")]
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["num_processes"] == 1
    entry = manifest["tree"]["params/w"]
    assert entry["shape"] == [2, 3] and entry["dtype"] == "float32"
    # every shard records its global index — the addressable contract
    assert all("index" in s and "file" in s for s in entry["shards"])

    assert ckpt_lib.read_metadata(path) == {"step": 1, "mode": "lm"}
    restored, meta = ckpt_lib.restore(path, tree)
    assert meta["step"] == 1
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_torn_write_never_shadows_latest(tmp_path):
    tree = {"w": jnp.arange(4.0)}
    good = str(tmp_path / "step_2")
    ckpt_lib.save(good, tree, {"step": 2})
    # simulate a SIGKILL mid-save of step 4: shard files landed, the
    # manifest (completion marker) did not
    torn = str(tmp_path / "step_4")
    ckpt_lib.save(torn, tree, {"step": 4})
    os.remove(os.path.join(torn, ckpt_lib.MANIFEST))

    assert not ckpt_lib.is_complete(torn)
    assert ckpt_lib.is_complete(good)
    assert ckpt_lib.latest_step_path(str(tmp_path)) == good
    with pytest.raises(FileNotFoundError, match="never completed"):
        ckpt_lib.restore(torn, tree)


def test_restore_validates_structure_up_front(tmp_path):
    path = str(tmp_path / "step_1")
    ckpt_lib.save(path, {"params": {"w": jnp.zeros(2), "b": jnp.zeros(3)}})
    template = {"params": {"w": jnp.zeros(2), "scale": jnp.zeros(3)}}
    with pytest.raises(ValueError) as err:
        ckpt_lib.restore(path, template)
    msg = str(err.value)
    # the aggregate diff names BOTH directions of the mismatch
    assert "params/scale" in msg and "params/b" in msg


def test_resume_config_mismatch_fails_loudly(tmp_path):
    """--resume checks the manifest's recorded run config (mode/env/arch)
    before reading any shard, and names the mismatched key."""
    from repro.launch import train as T
    d = str(tmp_path)
    T.main(["--mode", "rl-agent", "--env", "catch", "--batch", "8",
            "--steps", "2", "--checkpoint-dir", d])
    with pytest.raises(SystemExit, match="env.*catch.*gridworld"):
        T.main(["--mode", "rl-agent", "--env", "gridworld", "--batch", "8",
                "--steps", "4", "--checkpoint-dir", d, "--resume"])


def test_legacy_npz_checkpoint_still_restores(tmp_path):
    """Pre-manifest single-file checkpoints (the old format) stay readable
    through every read API."""
    path = str(tmp_path / "step_3.npz")
    schema = {"source": {"t": "dict",
                         "items": {"kind": {"t": "py", "v": "X"}}}}
    with open(path, "wb") as f:
        np.savez(f, **{"w": np.arange(4.0),
                       "__metadata__": json.dumps({"step": 3}),
                       "__structured_schema__": json.dumps(schema)})
    assert ckpt_lib.is_complete(path)
    assert ckpt_lib.latest_step_path(str(tmp_path)) == path
    assert ckpt_lib.read_metadata(path) == {"step": 3}
    restored, meta = ckpt_lib.restore(path, {"w": jnp.zeros(4)})
    assert meta["step"] == 3
    np.testing.assert_array_equal(restored["w"], np.arange(4.0))
    assert ckpt_lib.restore_structured(path, "source") == {"kind": "X"}
    flat, _ = ckpt_lib.load_flat(path)
    assert set(flat) == {"w"}


# ---------------------------------------------------------------------------
# background writer: off-hot-path writes, failure surfacing
# ---------------------------------------------------------------------------


def test_async_writer_writes_in_order_and_joins(tmp_path):
    lines = []
    w = AsyncCheckpointWriter(print_fn=lines.append)
    snap = ckpt_lib.snapshot({"x": jnp.arange(3.0)})
    w.submit(str(tmp_path / "step_1"), snap, {"step": 1})
    w.submit(str(tmp_path / "step_2"), snap, {"step": 2})
    w.flush()
    w.close()
    assert ckpt_lib.is_complete(str(tmp_path / "step_1"))
    assert ckpt_lib.is_complete(str(tmp_path / "step_2"))
    saved = [ln for ln in lines if ln.startswith("saved ")]
    assert saved == [f"saved {tmp_path}/step_1", f"saved {tmp_path}/step_2"]
    assert not w._thread  # joined — no writer thread outlives its run


def test_async_writer_failure_surfaces_on_flush(tmp_path):
    lines = []
    w = AsyncCheckpointWriter(print_fn=lines.append)
    snap = ckpt_lib.snapshot({"x": jnp.zeros(2)})
    blocker = tmp_path / "not-a-dir"
    blocker.write_text("occupied")
    w.submit(str(blocker / "step_1"), snap)
    with pytest.raises(CheckpointWriteError):
        w.flush()
    w.close(raise_on_error=False)
    assert any("checkpoint write failed" in ln for ln in lines)


def test_crash_checkpoint_failure_preserves_original_error(
        tmp_path, monkeypatch):
    """When the crash-path save itself dies, the ORIGINAL training failure
    must reach the caller — the save failure is logged, not raised."""
    from repro.configs.atari_impala import small_train
    from repro.core import learner as learner_lib
    from repro.core.runtime import Runtime
    from repro.core.sources import DeviceSource
    from repro.envs import catch
    from repro.models.convnet import init_agent, minatar_net
    from repro.optim import make_optimizer

    env = catch.make()
    init_fn, apply_fn = minatar_net(env.obs_shape, env.num_actions)
    params, _ = init_agent(init_fn, jax.random.PRNGKey(0))
    tc = small_train(unroll_length=3, batch_size=4, total_steps=6)
    opt = make_optimizer(tc)
    src = DeviceSource.for_env(env, apply_fn, unroll_length=3, batch_size=4,
                               key=jax.random.PRNGKey(1), pipelined=False)
    step = jax.jit(learner_lib.make_train_step(apply_fn, opt, tc))

    def no_disk(*a, **k):
        raise OSError("disk full")

    monkeypatch.setattr(ckpt_lib, "snapshot", no_disk)

    def boom(s, m):
        if s == 1:
            raise RuntimeError("the original failure")

    lines = []
    rt = Runtime(src, step, params, opt.init(params), total_steps=6,
                 log_every=0, checkpoint_dir=str(tmp_path), on_metrics=boom,
                 print_fn=lines.append)
    with pytest.raises(RuntimeError, match="the original failure"):
        rt.run()
    assert any("crash checkpoint failed" in ln and "disk full" in ln
               for ln in lines)


# ---------------------------------------------------------------------------
# sharded + elastic restore (4 forced devices, hermetic subprocess)
# ---------------------------------------------------------------------------

_SHARDED_RT = """
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import checkpoint as ckpt
from repro.launch.mesh import make_mesh2d

mesh = make_mesh2d(2, 2)
tree = {{"w": jax.device_put(jnp.arange(64.0).reshape(8, 8),
                             NamedSharding(mesh, P("data", "model"))),
         "b": jax.device_put(jnp.arange(8.0),
                             NamedSharding(mesh, P("model"))),
         "step": jnp.int32(7)}}
ckpt.save("{d}/step_1", tree, {{"step": 1}})
with open("{d}/step_1/manifest.json") as f:
    manifest = json.load(f)
entry = manifest["tree"]["w"]
assert entry["shape"] == [8, 8] and len(entry["shards"]) == 4
assert manifest["mesh"] == {{"data": 2, "model": 2}}

# same-mesh restore, straight onto devices
sh = {{k: v.sharding for k, v in tree.items()}}
out, meta = ckpt.restore("{d}/step_1", tree, shardings=sh)
assert meta["step"] == 1
for k in tree:
    assert out[k].sharding.is_equivalent_to(tree[k].sharding, tree[k].ndim)
    np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(tree[k]))

# ELASTIC: restore onto a (4,1) mesh the save never saw
mesh2 = make_mesh2d(4, 1)
sh2 = {{"w": NamedSharding(mesh2, P("data", "model")),
        "b": NamedSharding(mesh2, P("model")),
        "step": NamedSharding(mesh2, P())}}
out2, _ = ckpt.restore("{d}/step_1", tree, shardings=sh2)
for k in tree:
    np.testing.assert_array_equal(np.asarray(out2[k]), np.asarray(tree[k]))
assert len(out2["w"].sharding.device_set) == 4

# plain numpy assembly stitches the same bytes
flat, _ = ckpt.load_flat("{d}/step_1")
np.testing.assert_array_equal(flat["w"], np.arange(64.0).reshape(8, 8))
print("SHARDED-RT-OK")
"""


def test_sharded_save_elastic_restore_bitwise(tmp_path):
    proc = run_forced(script=_SHARDED_RT.format(d=tmp_path), devices=4)
    assert "SHARDED-RT-OK" in proc.stdout


_ELASTIC_PARITY = """
import json
from types import SimpleNamespace
import jax
jax.config.update("jax_default_matmul_precision", "highest")
from repro import checkpoint as ckpt
from repro.core.runtime import Runtime
from repro.launch import train as T


def run(md, mm, resume_from=None, ckdir=None, ckevery=0):
    a = SimpleNamespace(mode="lm", arch="xlstm-125m", reduced=True,
                        steps=6, batch=4, seq=16, lr=None,
                        mesh_data=md, mesh_model=mm,
                        attn_impl=None, ssd_impl=None)
    source, step_fn, params, opt_state, extras = T.build_lm(a)
    rs = extras.pop("restore_shardings", None)
    start = 0
    if resume_from is not None:
        restored, meta = ckpt.restore(
            resume_from, {{"params": params, "opt_state": opt_state}},
            shardings=rs)
        params, opt_state = restored["params"], restored["opt_state"]
        start = int(meta["step"])
        ss = ckpt.restore_structured(resume_from, "source")
        assert ss is not None
        source.load_state_dict(ss)
    losses = {{}}
    rt = Runtime(source, step_fn, params, opt_state, total_steps=6,
                 start_step=start, log_every=0, checkpoint_dir=ckdir,
                 checkpoint_every=ckevery, print_fn=lambda s: None,
                 on_metrics=lambda s, m: losses.__setitem__(
                     s, float(m["loss"])))
    rt.run()
    return losses


ref = run(2, 2, ckdir="{d}", ckevery=3)    # checkpoint on ("data","model")=(2,2)
ela = run(4, 1, resume_from="{d}/step_3")  # resume onto (4,1)
print("LOSSES " + json.dumps({{"ref": ref, "ela": ela}}))
"""


def test_elastic_resume_per_step_loss_parity(tmp_path):
    """An lm run checkpointed on mesh (2,2) and resumed on (4,1) replays
    the same batches and matches the uninterrupted run's per-step losses
    to 1e-5 — elastic resume preserves training, not just tensors."""
    proc = run_forced(script=_ELASTIC_PARITY.format(d=tmp_path), devices=4)
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("LOSSES ")][0]
    out = json.loads(line[len("LOSSES "):])
    assert sorted(out["ela"]) == ["3", "4", "5"]
    for s in out["ela"]:
        ref, ela = out["ref"][s], out["ela"][s]
        assert abs(ref - ela) <= 1e-5 * max(1.0, abs(ref)), (s, ref, ela)


# ---------------------------------------------------------------------------
# REAL multi-host fleet: 2 processes, loopback jax.distributed + gloo
# ---------------------------------------------------------------------------


def _lm2p_cmd(ckpt_dir, extra=()):
    return ["-m", "repro.launch.train", "--mode", "lm",
            "--arch", "xlstm-125m", "--reduced", "--steps", "6",
            "--batch", "4", "--seq", "16", "--mesh-data", "2",
            "--checkpoint-dir", ckpt_dir, *extra]


def test_two_process_sigkill_resume_bit_exact(tmp_path):
    """The acceptance run: a 2-process fleet (1 device each, the mesh
    spans both hosts) checkpoints cooperatively — each process writes its
    own shards — survives SIGKILL of EVERY process mid-run, and --resume
    reaches final params bitwise equal to the uninterrupted fleet."""
    dir_a, dir_b = str(tmp_path / "a"), str(tmp_path / "b")

    # leg A: uninterrupted
    res = run_coordinated(_lm2p_cmd(dir_a), 2, devices=1)
    assert all(rc == 0 for rc, _ in res), "\n".join(o for _, o in res)
    step6 = os.path.join(dir_a, "step_6")
    assert ckpt_lib.is_complete(step6)
    files = os.listdir(step6)
    assert "shard-00000.npz" in files and "shard-00001.npz" in files

    # leg B: SIGKILL the whole fleet once the step-3 boundary completes
    marker = os.path.join(dir_b, "step_3", "manifest.json")
    run_coordinated(_lm2p_cmd(dir_b, ["--checkpoint-every", "3"]), 2,
                    devices=1, kill_marker=marker)
    assert os.path.exists(marker)
    prune_after(dir_b, 3)

    # leg C: resume the fleet to the same horizon
    res = run_coordinated(_lm2p_cmd(dir_b, ["--resume"]), 2, devices=1)
    assert all(rc == 0 for rc, _ in res), "\n".join(o for _, o in res)
    assert any("resumed" in o and "at step 3" in o for _, o in res)

    flat_a, _ = ckpt_lib.load_flat(os.path.join(dir_a, "step_6"))
    flat_b, _ = ckpt_lib.load_flat(os.path.join(dir_b, "step_6"))
    assert set(flat_a) == set(flat_b) and flat_a
    for k in flat_a:
        np.testing.assert_array_equal(flat_a[k], flat_b[k], err_msg=k)
