import glob
import os
import re
import signal
import subprocess
import sys
import time

# Tests see the single real CPU device (the 512-device override is dryrun's
# alone); cap compilation parallelism for stability.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# ---------------------------------------------------------------------------
# Multi-device subprocess harness, shared by test_sharded / test_resume /
# test_mesh2d: the tier-1 env has ONE device, so every mesh>1 test runs in
# a hermetic subprocess under a forced XLA host device count.
# ---------------------------------------------------------------------------


def forced_cpu_env(num_devices: int) -> dict:
    """A subprocess environment with ``num_devices`` forced XLA host CPU
    devices and ``src/`` importable — any inherited device-count forcing
    is replaced, not appended to."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env["JAX_PLATFORMS"] = "cpu"
    flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                   env.get("XLA_FLAGS", ""))
    env["XLA_FLAGS"] = (flags + " --xla_force_host_platform_device_count="
                        + str(num_devices)).strip()
    return env


def run_forced(args=None, *, script=None, devices=8, timeout=600,
               check=True):
    """Run ``python -c script`` (or ``python *args``) under
    ``forced_cpu_env(devices)``; with ``check`` (default) a non-zero exit
    fails the test with the subprocess output attached."""
    cmd = [sys.executable] + (["-c", script] if script is not None
                              else list(args))
    proc = subprocess.run(cmd, env=forced_cpu_env(devices),
                          capture_output=True, text=True, timeout=timeout)
    if check:
        assert proc.returncode == 0, proc.stdout + proc.stderr
    return proc


def sigkill_at_boundary(cmd, ckpt_dir, boundary_step, *, devices,
                        deadline_s=540):
    """Launch ``python *cmd`` under forced devices, SIGKILL it once the
    ``step_{boundary_step}`` boundary checkpoint lands, then prune any
    later checkpoints so a subsequent --resume provably starts from
    mid-run state (if the run outraces the kill, pruning still leaves a
    genuine boundary checkpoint — the kill adds realism, not
    correctness). Shared by the rl-agent (test_resume) and lm
    (test_mesh2d) kill/resume suites."""
    marker = os.path.join(ckpt_dir, f"step_{boundary_step}.npz")
    p = subprocess.Popen([sys.executable] + list(cmd),
                         env=forced_cpu_env(devices),
                         stdout=subprocess.DEVNULL,
                         stderr=subprocess.DEVNULL)
    try:
        deadline = time.time() + deadline_s
        while time.time() < deadline and p.poll() is None:
            if os.path.exists(marker):
                p.send_signal(signal.SIGKILL)
                break
            time.sleep(0.05)
        p.wait(timeout=60)
    finally:
        if p.poll() is None:
            p.kill()
    assert os.path.exists(marker)
    for f in glob.glob(os.path.join(ckpt_dir, "step_*.npz")):
        if int(os.path.basename(f)[5:-4]) > boundary_step:
            os.remove(f)
