import os

# Tests see the single real CPU device (the 512-device override is dryrun's
# alone); cap compilation parallelism for stability.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")
