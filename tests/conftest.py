import glob
import os
import re
import shutil
import signal
import socket
import subprocess
import sys
import time

# Tests see the single real CPU device (the 512-device override is dryrun's
# alone); cap compilation parallelism for stability.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# ---------------------------------------------------------------------------
# Multi-device subprocess harness, shared by test_sharded / test_resume /
# test_mesh2d: the tier-1 env has ONE device, so every mesh>1 test runs in
# a hermetic subprocess under a forced XLA host device count.
# ---------------------------------------------------------------------------


def forced_cpu_env(num_devices: int) -> dict:
    """A subprocess environment with ``num_devices`` forced XLA host CPU
    devices and ``src/`` importable — any inherited device-count forcing
    is replaced, not appended to."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env["JAX_PLATFORMS"] = "cpu"
    flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                   env.get("XLA_FLAGS", ""))
    env["XLA_FLAGS"] = (flags + " --xla_force_host_platform_device_count="
                        + str(num_devices)).strip()
    return env


def run_forced(args=None, *, script=None, devices=8, timeout=600,
               check=True):
    """Run ``python -c script`` (or ``python *args``) under
    ``forced_cpu_env(devices)``; with ``check`` (default) a non-zero exit
    fails the test with the subprocess output attached."""
    cmd = [sys.executable] + (["-c", script] if script is not None
                              else list(args))
    proc = subprocess.run(cmd, env=forced_cpu_env(devices),
                          capture_output=True, text=True, timeout=timeout)
    if check:
        assert proc.returncode == 0, proc.stdout + proc.stderr
    return proc


def _ckpt_step(path: str) -> int:
    """Step number of a ``step_N`` manifest dir or legacy ``step_N.npz``."""
    name = os.path.basename(path.rstrip("/"))
    return int(name[5:-4] if name.endswith(".npz") else name[5:])


def prune_after(ckpt_dir, boundary_step):
    """Remove every checkpoint (manifest dir or legacy .npz) later than
    ``boundary_step`` so --resume provably starts from mid-run state."""
    for f in glob.glob(os.path.join(ckpt_dir, "step_*")):
        if _ckpt_step(f) > boundary_step:
            shutil.rmtree(f) if os.path.isdir(f) else os.remove(f)


def sigkill_at_boundary(cmd, ckpt_dir, boundary_step, *, devices,
                        deadline_s=540):
    """Launch ``python *cmd`` under forced devices, SIGKILL it once the
    ``step_{boundary_step}`` boundary checkpoint COMPLETES (its
    ``manifest.json`` completion marker exists — shard files may land
    earlier), then prune any later checkpoints so a subsequent --resume
    provably starts from mid-run state (if the run outraces the kill,
    pruning still leaves a genuine boundary checkpoint — the kill adds
    realism, not correctness). Shared by the rl-agent (test_resume) and
    lm (test_mesh2d) kill/resume suites."""
    marker = os.path.join(ckpt_dir, f"step_{boundary_step}", "manifest.json")
    p = subprocess.Popen([sys.executable] + list(cmd),
                         env=forced_cpu_env(devices),
                         stdout=subprocess.DEVNULL,
                         stderr=subprocess.DEVNULL)
    try:
        deadline = time.time() + deadline_s
        while time.time() < deadline and p.poll() is None:
            if os.path.exists(marker):
                p.send_signal(signal.SIGKILL)
                break
            time.sleep(0.05)
        p.wait(timeout=60)
    finally:
        if p.poll() is None:
            p.kill()
    assert os.path.exists(marker)
    prune_after(ckpt_dir, boundary_step)


# ---------------------------------------------------------------------------
# Coordinated multi-process harness (real jax.distributed over loopback,
# gloo CPU collectives): each test process is one host of the fleet.
# ---------------------------------------------------------------------------


def free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


_CONNECT_ERRS = ("DEADLINE_EXCEEDED", "UNAVAILABLE", "failed to connect",
                 "Connection refused", "Permission denied",
                 "coordination service")


def run_coordinated(cmd, num_processes, *, devices=1, timeout=600,
                    kill_marker=None, deadline_s=540):
    """Run ``python *cmd`` once per process with
    --coordinator/--num-processes/--process-id appended (fresh loopback
    port), returning the list of (returncode, output) per process.

    With ``kill_marker`` set (a path), every process is SIGKILLed as soon
    as the marker exists — the multi-host mid-run kill harness.

    Skips the calling test when the fleet cannot form because the
    environment forbids loopback gRPC (sandboxed runners) — the CI
    sharded-cpu job runs the same flow unconditionally."""
    import pytest

    port = free_port()
    procs = []
    for pid in range(num_processes):
        full = [sys.executable] + list(cmd) + [
            "--coordinator", f"127.0.0.1:{port}",
            "--num-processes", str(num_processes),
            "--process-id", str(pid)]
        procs.append(subprocess.Popen(
            full, env=forced_cpu_env(devices),
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True))
    killed = False
    if kill_marker is not None:
        deadline = time.time() + deadline_s
        while (time.time() < deadline
               and any(p.poll() is None for p in procs)):
            if os.path.exists(kill_marker):
                for p in procs:
                    if p.poll() is None:
                        p.send_signal(signal.SIGKILL)
                killed = True
                break
            time.sleep(0.05)
    results = []
    try:
        for p in procs:
            out, _ = p.communicate(timeout=timeout)
            results.append((p.returncode, out))
    finally:
        for p in procs:
            if p.poll() is None:
                p.kill()
    if not killed:
        blob = "\n".join(out for _, out in results)
        if (any(rc != 0 for rc, _ in results)
                and any(e in blob for e in _CONNECT_ERRS)):
            pytest.skip("loopback jax.distributed unavailable "
                        "in this environment")
    return results
