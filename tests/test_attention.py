"""Attention implementation equivalences (dense vs chunked vs chunked-skip)
and decode-cache semantics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import attention as A


def _cfg(**over):
    base = get_reduced_config("qwen3-32b")
    return dataclasses.replace(base, **over)


def _setup(cfg, b=2, s=128, key=0):
    k = jax.random.PRNGKey(key)
    params = jax.tree.map(
        lambda p: p.value if hasattr(p, "value") else p,
        A.attn_init(k, cfg, "attn"),
        is_leaf=lambda x: hasattr(x, "value"))
    x = jax.random.normal(jax.random.fold_in(k, 1), (b, s, cfg.d_model),
                          jnp.float32)
    return params, x


@pytest.mark.parametrize("kind,softcap", [
    ("attn", None), ("swa_attn", None), ("attn", 30.0)])
def test_chunked_matches_dense(kind, softcap):
    cfg = _cfg(attn_logit_softcap=softcap, sliding_window=48, attn_chunk=32)
    params, x = _setup(cfg)
    pos = jnp.arange(x.shape[1])
    outs = {}
    for impl in ("xla", "xla_chunked", "xla_chunked_skip"):
        outs[impl], _ = A.attn_apply(params, x, cfg=cfg, kind=kind,
                                     positions=pos, impl=impl)
    np.testing.assert_allclose(outs["xla"], outs["xla_chunked"],
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(outs["xla"], outs["xla_chunked_skip"],
                               rtol=2e-5, atol=2e-5)


def test_chunked_grads_match_dense():
    cfg = _cfg(attn_chunk=32)
    params, x = _setup(cfg, s=64)
    pos = jnp.arange(64)

    def loss(impl):
        def f(x):
            o, _ = A.attn_apply(params, x, cfg=cfg, kind="attn",
                                positions=pos, impl=impl)
            return jnp.sum(jnp.square(o.astype(jnp.float32)))
        return jax.grad(f)(x)

    np.testing.assert_allclose(loss("xla"), loss("xla_chunked"),
                               rtol=3e-4, atol=3e-4)


def test_qk_norm_changes_output():
    cfg_on = _cfg(use_qk_norm=True)
    params, x = _setup(cfg_on, s=32)
    pos = jnp.arange(32)
    o1, _ = A.attn_apply(params, x, cfg=cfg_on, kind="attn", positions=pos,
                         impl="xla")
    cfg_off = dataclasses.replace(cfg_on, use_qk_norm=False)
    o2, _ = A.attn_apply(params, x, cfg=cfg_off, kind="attn", positions=pos,
                         impl="xla")
    assert float(jnp.abs(o1 - o2).max()) > 1e-6


def test_decode_ring_buffer_positions():
    """Ring-buffer slot->position bookkeeping: decode with a window-sized
    cache equals dense windowed attention at every step."""
    cfg = _cfg(sliding_window=16, attn_chunk=16)
    params, x = _setup(cfg, b=1, s=40)
    pos = jnp.arange(40)
    full, _ = A.attn_apply(params, x, cfg=cfg, kind="swa_attn",
                           positions=pos, impl="xla")
    cache = A.attn_cache_init(cfg, "swa_attn", 1, 40, x.dtype)
    for t in range(40):
        out, cache = A.attn_decode(params, x[:, t:t + 1], cache, cfg=cfg,
                                   kind="swa_attn", pos=jnp.int32(t))
        np.testing.assert_allclose(out[:, 0], full[:, t], rtol=2e-4,
                                   atol=2e-4)


def test_cross_attention_ignores_causal():
    cfg = _cfg(vision_seq=24)
    params, x = _setup(cfg, s=16)
    vis = jax.random.normal(jax.random.PRNGKey(9), (2, 24, cfg.d_model))
    pos = jnp.arange(16)
    o, (k, v) = A.attn_apply(params, x, cfg=cfg, kind="xattn",
                             positions=pos, kv_src=vis, impl="xla")
    assert k.shape[1] == 24
    # permuting query positions permutes outputs identically (no causality)
    perm = jnp.array(list(reversed(range(16))))
    o2, _ = A.attn_apply(params, x[:, perm], cfg=cfg, kind="xattn",
                         positions=pos, kv_src=vis, impl="xla")
    np.testing.assert_allclose(o[:, perm], o2, rtol=2e-5, atol=2e-5)
