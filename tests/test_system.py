"""End-to-end system behaviour (the paper's claims at CPU scale):
IMPALA learns; the host-loop (MonoBeast) and on-device (PolyBeast->TPU)
actor paths feed the same learner; LM pretraining learns; generation is
behavior-consistent with the model."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced_config
from repro.configs.atari_impala import small_train
from repro.configs.base import TrainConfig
from repro.core import generate as gen_lib
from repro.core import learner as learner_lib
from repro.core import rollout as rollout_lib
from repro.data import PackedBatchIterator, markov_corpus
from repro.envs import catch
from repro.models import model as model_lib
from repro.models.convnet import init_agent, minatar_net
from repro.optim import make_optimizer


def _run_impala_catch(steps, lr=2e-3, batch=32, seed=0):
    env = catch.make()
    tc = small_train(unroll_length=20, batch_size=batch, learning_rate=lr,
                     total_steps=steps + 1000)
    init_fn, apply_fn = minatar_net(env.obs_shape, env.num_actions)
    params, _ = init_agent(init_fn, jax.random.PRNGKey(seed))
    opt = make_optimizer(tc)
    opt_state = opt.init(params)
    key = jax.random.PRNGKey(seed + 1)
    carry = rollout_lib.env_reset_batch(env, key, batch)
    unroll = rollout_lib.make_unroll(env, apply_fn, tc.unroll_length)
    train_step = learner_lib.make_train_step(apply_fn, opt, tc)

    @jax.jit
    def combined(params, opt_state, step, carry, key):
        carry, ro = unroll(params, carry, key)
        params, opt_state, m = train_step(params, opt_state, step, ro)
        return params, opt_state, carry, m

    rewards = []
    for step in range(steps):
        key, k = jax.random.split(key)
        params, opt_state, carry, m = combined(
            params, opt_state, jnp.int32(step), carry, k)
        rewards.append(float(m["reward_per_step"]))
    return rewards


def test_impala_learns_catch():
    """Fig 3/4 analogue at CPU scale: reward/step must climb from random
    (~-0.06) clearly toward optimal (+0.1)."""
    rewards = _run_impala_catch(700)
    early = np.mean(rewards[:50])
    late = np.mean(rewards[-50:])
    assert late > early + 0.08, (early, late)
    assert late > 0.05, late


def test_lm_pretraining_learns():
    cfg = get_reduced_config("qwen3-4b")
    tc = TrainConfig(optimizer="adamw", learning_rate=1e-3, grad_clip=1.0,
                     lr_schedule="constant")
    params, _ = model_lib.init(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer(tc)
    opt_state = opt.init(params)
    step_fn = jax.jit(learner_lib.make_lm_pretrain_step(cfg, opt,
                                                        loss_chunk=32))
    corpus = markov_corpus(cfg.vocab_size, 50_000, seed=3, branching=2)
    it = PackedBatchIterator(corpus, 8, 32)
    losses = []
    try:
        for step in range(60):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            params, opt_state, m = step_fn(params, opt_state,
                                           jnp.int32(step), batch)
            losses.append(float(m["loss"]))
    finally:
        it.close()
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])


def test_generate_behavior_logprob_consistent():
    """The behavior log-probs recorded by generation must equal the
    log-probs the learner recomputes for the same tokens — the V-trace
    contract (rho == 1 when behavior == target)."""
    cfg = get_reduced_config("qwen3-4b")
    params, _ = model_lib.init(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 1), 0,
                                cfg.vocab_size)
    out = gen_lib.generate(params, prompt, jax.random.PRNGKey(2), cfg=cfg,
                           num_steps=15)
    tokens = out["tokens"]  # (2, 16)
    logits, _, _ = model_lib.apply_lm(params, tokens[:, :-1], cfg=cfg)
    lp = jax.nn.log_softmax(logits, axis=-1)
    relp = jnp.take_along_axis(lp, tokens[:, 1:][..., None], -1)[..., 0]
    np.testing.assert_allclose(out["logprob"], relp, rtol=2e-3, atol=2e-3)


def test_generate_shapes_and_determinism():
    cfg = get_reduced_config("xlstm-125m")
    params, _ = model_lib.init(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (3, 4), 0,
                                cfg.vocab_size)
    a = gen_lib.generate(params, prompt, jax.random.PRNGKey(7), cfg=cfg,
                         num_steps=8)
    b = gen_lib.generate(params, prompt, jax.random.PRNGKey(7), cfg=cfg,
                         num_steps=8)
    assert a["tokens"].shape == (3, 12)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert bool((a["tokens"][:, :4] == prompt).all())


def test_host_loop_matches_rollout_contract():
    """MonoBeast-style actor pool -> learner queue produces batches with the
    exact learner-input layout of §2 of the paper, and the learner consumes
    them."""
    from repro.core.actor_pool import ActorPool, start_inference_thread
    from repro.core.batcher import BatchingQueue, DynamicBatcher
    from repro.envs.base import HostEnv

    env0 = catch.make()
    tc = small_train(unroll_length=5, batch_size=4, num_actors=4)
    init_fn, apply_fn = minatar_net(env0.obs_shape, env0.num_actions)
    params, _ = init_agent(init_fn, jax.random.PRNGKey(0))

    policy = jax.jit(lambda obs: apply_fn(params, obs).policy_logits)
    inference = DynamicBatcher(max_batch_size=4, timeout_ms=5)
    learner_queue = BatchingQueue(tc.batch_size, batch_dim=1)
    pool = ActorPool(lambda seed: HostEnv(env0, seed), tc.num_actors,
                     tc.unroll_length, inference, learner_queue)
    start_inference_thread(inference, lambda obs: policy(jnp.asarray(obs)))
    pool.start()
    try:
        batch = learner_queue.get(timeout=60)
        assert batch is not None
        t, b = tc.unroll_length, tc.batch_size
        assert batch["obs"].shape == (t + 1, b, 10, 5, 1)
        assert batch["action"].shape == (t, b)
        assert batch["behavior_logits"].shape == (t, b, env0.num_actions)
        assert batch["reward"].shape == (t, b)

        opt = make_optimizer(tc)
        opt_state = opt.init(params)
        train_step = jax.jit(learner_lib.make_train_step(apply_fn, opt, tc))
        jbatch = {k: jnp.asarray(v) for k, v in batch.items()}
        _, _, m = train_step(params, opt_state, jnp.int32(0), jbatch)
        assert bool(jnp.isfinite(m["loss"]))
    finally:
        pool.stop()
