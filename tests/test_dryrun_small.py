"""Dry-run machinery on a small (2,2) host mesh with reduced configs:
exercises specs.build_program / sharding rules / collective parsing without
the 512-device override (subprocess-free)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.configs.base import InputShape
from repro.distributed.sharding import RULE_SETS
from repro.launch.dryrun import collective_bytes
from repro.launch.specs import build_program

# runs on the plain 1-device CPU test env (meshes below are (1,1))


def _mesh():
    # single-device mesh with both axis names: exercises the full path
    from repro.launch.mesh import make_mesh
    return make_mesh((1, 1), ("data", "model"))


SMALL_SHAPES = {
    "train": InputShape("train_small", 64, 4, "train"),
    "prefill": InputShape("prefill_small", 64, 2, "prefill"),
    "decode": InputShape("decode_small", 64, 4, "decode"),
}


@pytest.mark.parametrize("arch", ["qwen3-4b", "mixtral-8x7b", "zamba2-2.7b",
                                  "llama-3.2-vision-90b"])
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_build_and_compile_small(arch, kind):
    mesh = _mesh()
    cfg = get_reduced_config(arch)
    fn, args, rcfg, jit_kwargs = build_program(
        arch, SMALL_SHAPES[kind], mesh, RULE_SETS["megatron"], base_cfg=cfg)
    with mesh:
        compiled = jax.jit(fn, **jit_kwargs).lower(*args).compile()
    mem = compiled.memory_analysis()
    assert mem.temp_size_in_bytes >= 0
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    assert ca.get("flops", 0) > 0


def test_collective_parser():
    hlo = """
  %x = bf16[16,512]{1,0} all-reduce(%a), replica_groups={}
  %y = (f32[8,8]{1,0}, f32[4]{0}) all-gather(%b, %c)
  %z = f32[128]{0} reduce-scatter(%d)
  %w = bf16[2,2]{1,0} all-to-all(%e)
  %p = s32[10]{0} collective-permute(%f)
  %n = f32[99]{0} add(%g, %h)
"""
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 16 * 512 * 2 * 2.0   # 2x wire factor
    assert got["all-gather"] == (64 + 4) * 4
    assert got["reduce-scatter"] == 128 * 4
    assert got["all-to-all"] == 2 * 2 * 2
    assert got["collective-permute"] == 10 * 4


def test_train_step_executes_on_small_mesh():
    """The built train program actually RUNS (not just compiles) on the
    1-device mesh with real arrays, and the loss is finite."""
    from repro.models import model as M
    mesh = _mesh()
    cfg = get_reduced_config("qwen3-4b")
    shape = SMALL_SHAPES["train"]
    fn, args, rcfg, jit_kwargs = build_program(
        "qwen3-4b", shape, mesh, RULE_SETS["megatron"], base_cfg=cfg)
    params_sds, opt_sds, step_sds, batch_sds = args
    key = jax.random.PRNGKey(0)
    params = jax.tree.map(
        lambda s: 0.02 * jax.random.normal(key, s.shape, s.dtype)
        if jnp.issubdtype(s.dtype, jnp.floating)
        else jnp.zeros(s.shape, s.dtype), params_sds)
    opt_state = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), opt_sds)
    rng = np.random.default_rng(0)
    b, s = shape.global_batch, shape.seq_len
    batch = {
        "tokens": jnp.asarray(rng.integers(0, rcfg.vocab_size, (b, s + 1)),
                              jnp.int32),
        "behavior_logprob": jnp.full((b, s), -np.log(rcfg.vocab_size),
                                     jnp.float32),
        "reward": jnp.asarray(rng.normal(0, 1, (b, s)), jnp.float32),
        "done": jnp.zeros((b, s), bool).at[:, -1].set(True),
    }
    with mesh:
        out = jax.jit(fn, **jit_kwargs)(params, opt_state, jnp.int32(0),
                                        batch)
    _, _, metrics = out
    assert bool(jnp.isfinite(metrics["loss"]))


def test_long_context_override_is_windowed_attention():
    """resolve_config's long_500k variant for full-attention archs must
    equal sliding-window attention with the configured window (same
    params, swapped mixer kind)."""
    import dataclasses
    import jax.numpy as jnp
    from repro.launch.specs import resolve_config
    from repro.configs import get_reduced_config
    from repro.models import model as M

    base = get_reduced_config("qwen3-4b")
    # mimic the override at reduced scale
    lc = dataclasses.replace(
        base, block_pattern=(("swa_attn", "swiglu"),),
        sliding_window=base.long_context_window)  # = 64 reduced
    params, _ = M.init(jax.random.PRNGKey(0), base)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 48), 0,
                                base.vocab_size)
    # same params work for both (identical param structure)
    full, _, _ = M.apply_lm(params, tokens, cfg=base)
    win, _, _ = M.apply_lm(params, tokens, cfg=lc)
    # within the window (48 < 64) they agree exactly
    np.testing.assert_allclose(np.asarray(full), np.asarray(win),
                               rtol=2e-4, atol=2e-4)
    # beyond the window they must differ (the window is real)
    lc_small = dataclasses.replace(lc, sliding_window=16)
    win2, _, _ = M.apply_lm(params, tokens, cfg=lc_small)
    assert float(jnp.abs(full - win2).max()) > 1e-4


def test_resolve_config_long_override_applies():
    from repro.launch.specs import resolve_config
    cfg = resolve_config("qwen3-32b", "long_500k")
    assert all(m == "swa_attn" for m, _ in cfg.block_pattern)
    assert cfg.sliding_window == cfg.long_context_window
    # native sub-quadratic archs keep their pattern
    cfg2 = resolve_config("zamba2-2.7b", "long_500k")
    assert all(m == "mamba" for m, _ in cfg2.block_pattern)


def test_multihost_cli_single_host_dryrun(capsys):
    from repro.launch import multihost
    multihost.main(["--mode", "dryrun", "--arch", "xlstm-125m",
                    "--shape", "decode_32k"])
    out = capsys.readouterr().out
    assert "compiled xlstm-125m/decode_32k" in out
